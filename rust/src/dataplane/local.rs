//! Reference in-process driver: the Storm dataplane over local shards.
//!
//! Executes the sans-io engines ([`LookupSm`], [`TxEngine`]) directly
//! against in-memory storage catalogs ([`Catalog`]: one backend per
//! object, so multi-object workloads like four-table TATP run natively)
//! with no fabric at all. This is the semantic reference: what the
//! simulator and the live loopback driver must agree with. Used heavily
//! by tests (including step-interleaved concurrency tests for the OCC
//! protocol) and the quickstart example.
//!
//! Since PR 5 the reference driver hosts **heterogeneous catalogs**
//! ([`LocalCluster::new_hetero`]): B-link objects resolve through the
//! shared [`BTreeRouteResolver`] (cached-route leaf reads, RPC
//! re-traversal + repair on fence miss) and join transactions at leaf
//! granularity; hopscotch objects resolve via owner RPCs and — since
//! PR 10 — join transactions at slot (item) granularity: a lock-read
//! pins the slot against displacement and validation reads its 16-byte
//! slot header one-sided. Queue objects are the RPC-only kind now
//! (`Enqueue`/`Dequeue` through the owner; a tx write-set item naming
//! one aborts with the typed `Unsupported`).
//!
//! The batched engine contract is driven here with a window of one:
//! emitted [`TxPost`]s queue up and are served strictly in order
//! ([`LocalCluster::run_tx_posts`]), while tests that need explicit
//! interleavings serve individual posts via
//! [`LocalCluster::serve_tx_post`] and park the rest.

use std::collections::VecDeque;

use crate::ds::api::{LookupHint, LookupOutcome, ObjectId, RpcRequest, RpcResponse, RpcResult};
use crate::ds::btree::{BTreeRouteResolver, LEAF_BYTES};
use crate::ds::catalog::{Backend, Catalog, CatalogConfig, ObjectConfig, ObjectKind};
use crate::ds::mica::{parse_item_view, MicaClient, MicaConfig};
use crate::mem::{PageSize, RegionMode, RemoteAddr};

use super::onetwo::{DsCallbacks, LkAction, LkInput, LkResult, LookupSm, ReadView};
use super::tx::{TxEngine, TxInput, TxItem, TxOp, TxOutcome, TxPost, TxStep};

/// One object's client-side resolver, kind-dispatched.
enum LocalObj {
    /// MICA: home-bucket hints + cached exact item addresses.
    Mica(MicaClient),
    /// B-link tree: the shared cached-route resolver.
    BTree(BTreeRouteResolver),
    /// Hopscotch and queue: the reference driver resolves these via
    /// owner RPCs (the live path's arithmetic neighborhood reads and
    /// cached queue pointers need the packed mirror, which the
    /// fabric-less driver does not build).
    Rpc,
}

/// Client-side state: one kind-dispatched resolver per catalog object,
/// plus the client's view of per-node **leases**. A lease here is purely
/// logical (no wall clock — everything stays deterministic): the client
/// holds each node's lease until it observes the node failed or fenced,
/// expires it via [`LocalClient::expire_lease`], and from then on routes
/// that node's keys to the next live replica — the client-observed
/// **promotion** of a backup. A recovered node re-admits via
/// [`LocalClient::renew_lease`].
pub struct LocalClient {
    objs: Vec<LocalObj>,
    kinds: Vec<ObjectKind>,
    nodes: u32,
    rpc_only: bool,
    replication: u32,
    alive: Vec<bool>,
}

impl LocalClient {
    /// Expire a node's lease: writes (and RPC-routed reads) for keys it
    /// primaries re-route to the next live replica. One-sided read hints
    /// are unaffected (the reference driver's resolvers address node
    /// memory directly) — failover tests drive the RPC-only client,
    /// where every action routes through [`DsCallbacks::owner`].
    pub fn expire_lease(&mut self, node: u32) {
        self.alive[node as usize] = false;
    }

    /// Re-admit a recovered node (its lease is considered re-granted).
    pub fn renew_lease(&mut self, node: u32) {
        self.alive[node as usize] = true;
    }

    /// The key's replica chain (primary first), ignoring liveness.
    fn chain(&self, key: u64) -> impl Iterator<Item = u32> + '_ {
        let primary = crate::ds::mica::owner_of(key, self.nodes);
        (0..self.replication).map(move |i| (primary + i) % self.nodes)
    }
}

impl DsCallbacks for LocalClient {
    fn lookup_start(&mut self, obj: ObjectId, key: u64) -> Option<LookupHint> {
        if self.rpc_only {
            return None;
        }
        let node = crate::ds::mica::owner_of(key, self.nodes);
        match &mut self.objs[obj.0 as usize] {
            LocalObj::Mica(c) => Some(c.lookup_start(key)),
            LocalObj::BTree(b) => b.start(node, key),
            LocalObj::Rpc => None,
        }
    }
    fn lookup_end_read(&mut self, obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
        let node = crate::ds::mica::owner_of(key, self.nodes);
        match (&mut self.objs[obj.0 as usize], view) {
            (LocalObj::Mica(c), ReadView::Bucket(b)) => c.lookup_end_bucket(key, b),
            (LocalObj::Mica(c), ReadView::Item(i)) => c.lookup_end_item(key, *i),
            (LocalObj::BTree(b), ReadView::Leaf(leaf)) => b.end_read(node, key, leaf.as_ref()),
            // Kind/view mismatch: let the owner decide.
            _ => LookupOutcome::NeedRpc,
        }
    }
    fn lookup_end_rpc(&mut self, obj: ObjectId, key: u64, node: u32, resp: &RpcResponse) {
        match &mut self.objs[obj.0 as usize] {
            LocalObj::Mica(c) => {
                if let RpcResult::Value { addr, .. } = &resp.result {
                    c.record_rpc_addr(key, node, *addr);
                }
            }
            LocalObj::BTree(b) => b.end_rpc(node, resp),
            LocalObj::Rpc => {}
        }
    }
    /// The first replica whose lease this client still holds — the
    /// primary in steady state, the promoted backup after an expiry.
    /// Falls back to the hash owner when every replica's lease expired
    /// (the request then surfaces a typed refusal instead of spinning —
    /// bounded unavailability).
    fn owner(&self, _obj: ObjectId, key: u64) -> u32 {
        self.chain(key)
            .find(|&nd| self.alive[nd as usize])
            .unwrap_or_else(|| crate::ds::mica::owner_of(key, self.nodes))
    }
    /// The live replica set (lease-expired nodes filtered), serving
    /// primary first — what the commit phase replicates across. Degraded
    /// replication while a replica is down is the protocol's choice: the
    /// commit must not block on a dead backup.
    fn replicas(&self, _obj: ObjectId, key: u64) -> Vec<u32> {
        let live: Vec<u32> = self.chain(key).filter(|&nd| self.alive[nd as usize]).collect();
        if live.is_empty() {
            vec![crate::ds::mica::owner_of(key, self.nodes)]
        } else {
            live
        }
    }
    fn backend_kind(&self, obj: ObjectId) -> ObjectKind {
        self.kinds[obj.0 as usize]
    }
}

/// An in-process "cluster": per-node storage catalogs + a way to run
/// engines to completion.
pub struct LocalCluster {
    /// Per-node storage: one [`Catalog`] per node, each holding a shard
    /// of every object.
    pub nodes: Vec<Catalog>,
    cat: CatalogConfig,
    next_tx: u64,
    /// Per-node fence flags: a fenced node refuses every write-class
    /// opcode with [`RpcResult::PrimaryFenced`] (lease revoked during
    /// failover, or restarted and not yet recovered) while still serving
    /// reads.
    fenced: Vec<bool>,
}

impl LocalCluster {
    /// Build `n` nodes, each holding a shard of every object. Object ids
    /// must be dense (`ObjectId(0)..ObjectId(len)` in any order) — the
    /// catalog indexes tables by id.
    pub fn new(n: u32, objects: Vec<(ObjectId, MicaConfig)>) -> Self {
        let mut objects = objects;
        objects.sort_by_key(|(o, _)| *o);
        for (i, (o, _)) in objects.iter().enumerate() {
            assert_eq!(o.0 as usize, i, "catalog object ids must be dense from 0");
        }
        Self::new_hetero(
            n,
            CatalogConfig::new(objects.into_iter().map(|(_, c)| c).collect()),
        )
    }

    /// Build `n` nodes hosting an arbitrary (possibly heterogeneous)
    /// catalog: MICA tables, B-link trees, and hopscotch objects.
    pub fn new_hetero(n: u32, cat: CatalogConfig) -> Self {
        let nodes = (0..n)
            .map(|_| Catalog::new(&cat, RegionMode::Virtual(PageSize::Huge2M)))
            .collect();
        LocalCluster { nodes, cat, next_tx: 1, fenced: vec![false; n as usize] }
    }

    /// Effective replication factor (the schema's, clamped to the
    /// cluster size).
    pub fn replication(&self) -> u32 {
        self.cat.replication.max(1).min(self.nodes.len() as u32)
    }

    /// The replica chain of a key: hash owner (primary) first, then its
    /// ring successors — the reference mirror of `Placement::replicas`.
    pub fn replicas_of(&self, key: u64) -> Vec<u32> {
        let n = self.nodes.len() as u32;
        let primary = crate::ds::mica::owner_of(key, n);
        (0..self.replication()).map(|i| (primary + i) % n).collect()
    }

    /// Revoke a node's write authority: every write-class RPC it serves
    /// from now on answers [`RpcResult::PrimaryFenced`]. Reads (and
    /// `Unlock`) keep serving.
    pub fn fence_node(&mut self, node: u32) {
        self.fenced[node as usize] = true;
    }

    /// Restore a node's write authority (after recovery).
    pub fn unfence_node(&mut self, node: u32) {
        self.fenced[node as usize] = false;
    }

    /// Crash a node (storage lost, node fenced) and rebuild its tables
    /// from its peers' replicas: for every object, pull each survivor's
    /// items, keep the keys whose replica chain includes the node, dedup
    /// across survivors by highest version, and install in key order —
    /// MICA versions are preserved exactly (the rebuilt table is
    /// byte-identical per item to the freshest surviving replica), tree
    /// and hopscotch objects rebuild value-preserving. The node stays
    /// fenced; [`LocalCluster::recover_node`] is the full restart.
    pub fn rebuild_node(&mut self, node: u32) {
        self.fenced[node as usize] = true;
        self.nodes[node as usize] = Catalog::new(&self.cat, RegionMode::Virtual(PageSize::Huge2M));
        let n = self.nodes.len() as u32;
        for o in 0..self.cat.len() {
            let obj = ObjectId(o as u32);
            let mut best: std::collections::HashMap<u64, (u32, Option<Vec<u8>>)> =
                std::collections::HashMap::new();
            for peer in 0..n {
                if peer == node {
                    continue;
                }
                for (key, version, value) in self.nodes[peer as usize].items(obj) {
                    if !self.replicas_of(key).contains(&node) {
                        continue;
                    }
                    match best.get(&key) {
                        Some((v, _)) if *v >= version => {}
                        _ => {
                            best.insert(key, (version, value));
                        }
                    }
                }
            }
            let mut keys: Vec<u64> = best.keys().copied().collect();
            keys.sort_unstable();
            for key in keys {
                let (version, value) = best.remove(&key).expect("key collected above");
                self.nodes[node as usize].install(obj, key, version, value.as_deref());
            }
        }
    }

    /// Full restart: rebuild the node's tables from its peers, then
    /// lift the fence — the node is a (backup) replica again. Clients
    /// re-admit it with [`LocalClient::renew_lease`].
    pub fn recover_node(&mut self, node: u32) {
        self.rebuild_node(node);
        self.fenced[node as usize] = false;
    }

    /// Build a client (resolver set) for this cluster.
    pub fn client(&self, with_cache: bool) -> LocalClient {
        let n = self.nodes.len() as u32;
        let objs = self
            .cat
            .objects
            .iter()
            .enumerate()
            .map(|(o, cfg)| {
                let obj = ObjectId(o as u32);
                match cfg {
                    ObjectConfig::Mica(mc) => {
                        let regions = self
                            .nodes
                            .iter()
                            .map(|nd| nd.table(obj).bucket_region)
                            .collect::<Vec<_>>();
                        let mut c = MicaClient::new(obj, mc, n, regions);
                        if with_cache {
                            c = c.with_cache();
                        }
                        LocalObj::Mica(c)
                    }
                    // Route caches start cold; the first lookup's RPC
                    // re-traversal warms them (exactly like a live
                    // client). Each node's catalog registers the tree
                    // region under the same key, so cached addresses are
                    // served against the right node's tree.
                    ObjectConfig::BTree(_) => {
                        LocalObj::BTree(BTreeRouteResolver::new(n, LEAF_BYTES))
                    }
                    ObjectConfig::Hopscotch(_) | ObjectConfig::Queue(_) => LocalObj::Rpc,
                }
            })
            .collect();
        let kinds = self.cat.objects.iter().map(|c| c.kind()).collect();
        LocalClient {
            objs,
            kinds,
            nodes: n,
            rpc_only: false,
            replication: self.replication(),
            alive: vec![true; n as usize],
        }
    }

    /// RPC-only client (Storm's RPC configuration / baselines).
    pub fn rpc_only_client(&self) -> LocalClient {
        let mut c = self.client(false);
        c.rpc_only = true;
        c
    }

    /// Fresh transaction id.
    pub fn next_tx_id(&mut self) -> u64 {
        let id = self.next_tx;
        self.next_tx += 1;
        id
    }

    /// Populate an object with keys (direct inserts on every node of
    /// each key's replica chain — the owner alone at replication 1).
    pub fn load(&mut self, obj: ObjectId, keys: impl Iterator<Item = u64>) {
        for key in keys {
            for node in self.replicas_of(key) {
                self.nodes[node as usize].insert(obj, key, None);
            }
        }
    }

    /// Serve a one-sided read against a node's memory, dispatched by the
    /// target object's backend kind (B-link reads come in two
    /// granularities: full leaves for lookups, bare headers for OCC
    /// validation).
    pub fn serve_read(&self, node: u32, obj_hint: ObjectId, addr: RemoteAddr, len: u32) -> ReadView {
        match self.nodes[node as usize].backend(obj_hint) {
            Backend::BTree(tree) => {
                if len >= LEAF_BYTES {
                    ReadView::Leaf(tree.leaf_view(addr))
                } else {
                    ReadView::LeafHeader(tree.leaf_header(addr))
                }
            }
            Backend::Mica(table) => {
                let bb = table.config().bucket_bytes();
                if len == bb && addr.region == table.bucket_region {
                    ReadView::Bucket(table.bucket_view(addr.offset / bb as u64))
                } else {
                    ReadView::Item(table.item_view(addr))
                }
            }
            // Hopscotch lookups are RPC-only here, but OCC validation
            // still reads the 16-byte slot header one-sided at the
            // address the lock-read reply cached.
            Backend::Hopscotch(table) => {
                let slot = addr.offset / table.item_size() as u64;
                if addr.region == table.region && slot < table.slot_count() {
                    ReadView::Item(parse_item_view(&table.slot_image(slot)))
                } else {
                    ReadView::Item(None)
                }
            }
            // Queue resolvers are RPC-only in the reference driver: no
            // resolver ever issues a one-sided read against one.
            other => panic!(
                "one-sided read against a {} backend in the reference driver",
                other.kind_name()
            ),
        }
    }

    /// Serve an RPC on the owner node (the catalog's `rpc_handler`,
    /// dispatched by the request's object id). A fenced node refuses the
    /// write-class opcodes before they reach storage — a stale lease
    /// holder can never commit through a deposed primary (invariant L2).
    pub fn serve_rpc(&mut self, node: u32, req: &RpcRequest) -> RpcResponse {
        if self.fenced[node as usize] && req.op.is_write_class() {
            return RpcResponse::inline(RpcResult::PrimaryFenced);
        }
        self.nodes[node as usize].serve_rpc(req)
    }

    /// Run a single lookup to completion.
    pub fn run_lookup(&mut self, client: &mut LocalClient, obj: ObjectId, key: u64) -> LkResult {
        let mut sm = LookupSm::new(obj, key);
        let mut action = sm.advance(client, None);
        loop {
            match action {
                LkAction::Read { obj, node, addr, len, key: _ } => {
                    let view = self.serve_read(node, obj, addr, len);
                    action = sm.advance(client, Some(LkInput::Read(view)));
                }
                LkAction::Rpc { node, req } => {
                    let resp = self.serve_rpc(node, &req);
                    action = sm.advance(client, Some(LkInput::Rpc(resp)));
                }
                LkAction::Done(res) => return res,
            }
        }
    }

    /// Serve one posted action and feed its completion back, returning the
    /// engine's next step (callers drive interleavings explicitly in
    /// tests by parking the steps they are not ready to serve yet).
    pub fn serve_tx_post(
        &mut self,
        client: &mut LocalClient,
        engine: &mut TxEngine,
        post: &TxPost,
    ) -> TxStep {
        match &post.op {
            TxOp::Read { obj, node, addr, len, .. } => {
                let view = self.serve_read(*node, *obj, *addr, *len);
                engine.complete(client, post.tag, TxInput::Read(view))
            }
            TxOp::Rpc { node, req } => {
                let resp = self.serve_rpc(*node, req);
                engine.complete(client, post.tag, TxInput::Rpc(resp))
            }
        }
    }

    /// Drain a batch of posts (and everything the engine issues in
    /// response) to completion, serving strictly in order.
    pub fn run_tx_posts(
        &mut self,
        client: &mut LocalClient,
        engine: &mut TxEngine,
        posts: Vec<TxPost>,
    ) -> TxOutcome {
        let mut queue: VecDeque<TxPost> = posts.into();
        loop {
            let post = queue.pop_front().expect("engine stalled without posts");
            match self.serve_tx_post(client, engine, &post) {
                TxStep::Issue(more) => queue.extend(more),
                TxStep::Done(outcome) => {
                    assert!(queue.is_empty(), "engine finished with posts unserved");
                    return outcome;
                }
            }
        }
    }

    /// Run a transaction to completion.
    pub fn run_tx(
        &mut self,
        client: &mut LocalClient,
        read_set: Vec<TxItem>,
        write_set: Vec<TxItem>,
    ) -> TxOutcome {
        let tx_id = self.next_tx_id();
        let mut engine = TxEngine::begin(tx_id, read_set, write_set);
        match engine.start(client) {
            TxStep::Issue(posts) => self.run_tx_posts(client, &mut engine, posts),
            TxStep::Done(outcome) => outcome,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataplane::tx::AbortReason;

    const KV: ObjectId = ObjectId(0);

    fn cluster(nodes: u32, buckets: u64, width: u32) -> LocalCluster {
        LocalCluster::new(
            nodes,
            vec![(KV, MicaConfig { buckets, width, value_len: 112, store_values: false })],
        )
    }

    #[test]
    fn lookup_across_nodes() {
        let mut c = cluster(4, 1 << 10, 2);
        c.load(KV, 1..=1000);
        let mut client = c.client(false);
        for key in (1..=1000).step_by(97) {
            let res = c.run_lookup(&mut client, KV, key);
            assert!(res.found, "key {key}");
        }
        assert!(!c.run_lookup(&mut client, KV, 5555).found);
    }

    #[test]
    fn read_only_tx_commits() {
        let mut c = cluster(2, 1 << 10, 2);
        c.load(KV, 1..=100);
        let mut client = c.client(false);
        let outcome = c.run_tx(
            &mut client,
            vec![TxItem::read(KV, 1), TxItem::read(KV, 50), TxItem::read(KV, 100)],
            vec![],
        );
        assert!(matches!(outcome, TxOutcome::Committed { .. }));
    }

    #[test]
    fn update_tx_bumps_version_and_unlocks() {
        let mut c = cluster(2, 1 << 10, 2);
        c.load(KV, 1..=10);
        let mut client = c.client(false);
        let out = c.run_tx(&mut client, vec![], vec![TxItem::update(KV, 5)]);
        assert!(matches!(out, TxOutcome::Committed { .. }));
        // Version bumped from 1 -> 2 (lock_read) is not a bump; update is.
        let res = c.run_lookup(&mut client, KV, 5);
        assert_eq!(res.version, 2);
        assert!(!res.locked, "commit must release the lock");
    }

    #[test]
    fn insert_and_delete_through_tx() {
        let mut c = cluster(2, 1 << 10, 2);
        let mut client = c.client(false);
        let out = c.run_tx(&mut client, vec![], vec![TxItem::insert(KV, 777)]);
        assert!(matches!(out, TxOutcome::Committed { .. }));
        assert!(c.run_lookup(&mut client, KV, 777).found);
        let out = c.run_tx(&mut client, vec![], vec![TxItem::delete(KV, 777)]);
        assert!(matches!(out, TxOutcome::Committed { .. }));
        assert!(!c.run_lookup(&mut client, KV, 777).found);
    }

    /// Unwrap a step that must have issued actions.
    fn posts_of(step: TxStep) -> Vec<TxPost> {
        match step {
            TxStep::Issue(p) => p,
            TxStep::Done(o) => panic!("engine finished early: {o:?}"),
        }
    }

    #[test]
    fn lock_conflict_aborts_and_releases() {
        let mut c = cluster(1, 1 << 8, 2);
        c.load(KV, 1..=10);
        let mut client_a = c.client(false);
        let mut client_b = c.client(false);

        // Tx A locks key 3 (execute phase) and pauses before commit: serve
        // its lock-read but park the commit batch it issues in response.
        let mut tx_a = TxEngine::begin(100, vec![], vec![TxItem::update(KV, 3)]);
        let lock_posts = posts_of(tx_a.start(&mut client_a));
        assert_eq!(lock_posts.len(), 1);
        let commit_posts = posts_of(c.serve_tx_post(&mut client_a, &mut tx_a, &lock_posts[0]));
        assert_eq!(commit_posts.len(), 1, "lock held; commit volley parked");

        // Tx B tries to lock key 3 too: must abort with LockConflict.
        let mut tx_b = TxEngine::begin(200, vec![], vec![TxItem::update(KV, 3)]);
        let posts_b = posts_of(tx_b.start(&mut client_b));
        let out_b = c.run_tx_posts(&mut client_b, &mut tx_b, posts_b);
        assert_eq!(out_b, TxOutcome::Aborted(AbortReason::LockConflict));

        // A finishes its commit.
        let out_a = c.run_tx_posts(&mut client_a, &mut tx_a, commit_posts);
        assert!(matches!(out_a, TxOutcome::Committed { .. }));
        // Lock released: B can retry successfully.
        let out = c.run_tx(&mut client_b, vec![], vec![TxItem::update(KV, 3)]);
        assert!(matches!(out, TxOutcome::Committed { .. }));
    }

    #[test]
    fn concurrent_write_invalidates_reader() {
        let mut c = cluster(1, 1 << 8, 2);
        c.load(KV, 1..=10);
        let mut reader = c.client(false);
        let mut writer = c.client(false);

        // Reader executes (reads key 7, version 1): serve the execute-phase
        // read, then park the validation batch the engine issues.
        let mut tx_r = TxEngine::begin(300, vec![TxItem::read(KV, 7)], vec![]);
        let exec_posts = posts_of(tx_r.start(&mut reader));
        assert_eq!(exec_posts.len(), 1);
        let val_posts = posts_of(c.serve_tx_post(&mut reader, &mut tx_r, &exec_posts[0]));
        assert_eq!(val_posts.len(), 1, "validation read parked");
        // ...writer commits an update to key 7 in between...
        let out = c.run_tx(&mut writer, vec![], vec![TxItem::update(KV, 7)]);
        assert!(matches!(out, TxOutcome::Committed { .. }));
        // ...reader's validation read must now fail.
        let out = c.run_tx_posts(&mut reader, &mut tx_r, val_posts);
        assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationVersion));
    }

    #[test]
    fn validation_skips_items_we_wrote() {
        let mut c = cluster(1, 1 << 8, 2);
        c.load(KV, 1..=10);
        let mut client = c.client(false);
        // Read and update the same key: our own lock must not abort us.
        let out = c.run_tx(
            &mut client,
            vec![TxItem::read(KV, 4)],
            vec![TxItem::update(KV, 4)],
        );
        assert!(matches!(out, TxOutcome::Committed { .. }));
    }

    #[test]
    fn validation_locked_by_other_aborts() {
        let mut c = cluster(1, 1 << 8, 2);
        c.load(KV, 1..=10);
        let mut a = c.client(false);
        let mut b = c.client(false);

        // A reads key 9 (execute) and parks its validation batch.
        let mut tx_a = TxEngine::begin(400, vec![TxItem::read(KV, 9)], vec![]);
        let exec_posts = posts_of(tx_a.start(&mut a));
        let val_posts = posts_of(c.serve_tx_post(&mut a, &mut tx_a, &exec_posts[0]));

        // B acquires the lock on 9 and holds it (commit batch parked).
        let mut tx_b = TxEngine::begin(500, vec![], vec![TxItem::update(KV, 9)]);
        let lock_posts = posts_of(tx_b.start(&mut b));
        let _pending_b = posts_of(c.serve_tx_post(&mut b, &mut tx_b, &lock_posts[0]));

        // A validates: sees the foreign lock -> abort.
        let out = c.run_tx_posts(&mut a, &mut tx_a, val_posts);
        assert_eq!(out, TxOutcome::Aborted(AbortReason::ValidationLocked));
    }

    #[test]
    fn duplicate_update_keys_commit_once_through_reference_driver() {
        // Regression: two Updates naming the same key must not self-conflict
        // on the second lock-read; the lock is taken once and the single
        // UpdateUnlock bumps the version exactly once.
        let mut c = cluster(1, 1 << 8, 2);
        c.load(KV, 1..=10);
        let mut client = c.client(false);
        let out = c.run_tx(
            &mut client,
            vec![],
            vec![TxItem::update(KV, 6), TxItem::update(KV, 6)],
        );
        match out {
            TxOutcome::Committed { write_results } => {
                assert_eq!(write_results, vec![RpcResult::Ok, RpcResult::Ok]);
            }
            other => panic!("duplicate updates must commit, got {other:?}"),
        }
        let res = c.run_lookup(&mut client, KV, 6);
        assert_eq!(res.version, 2, "exactly one version bump");
        assert!(!res.locked, "lock released by the single commit op");
    }

    #[test]
    fn cross_object_tx_commits_and_tables_stay_independent() {
        let mica = |buckets| MicaConfig { buckets, width: 2, value_len: 112, store_values: false };
        let mut c = LocalCluster::new(
            2,
            vec![(ObjectId(0), mica(1 << 8)), (ObjectId(1), mica(1 << 6))],
        );
        c.load(ObjectId(0), 1..=20);
        c.load(ObjectId(1), 1..=20);
        let mut client = c.client(false);
        // Read table 0, write the same key in table 1: one transaction
        // spanning objects.
        let out = c.run_tx(
            &mut client,
            vec![TxItem::read(ObjectId(0), 9)],
            vec![TxItem::update(ObjectId(1), 9)],
        );
        assert!(matches!(out, TxOutcome::Committed { .. }));
        assert_eq!(c.run_lookup(&mut client, ObjectId(0), 9).version, 1);
        assert_eq!(c.run_lookup(&mut client, ObjectId(1), 9).version, 2);
        // Same key, different tables: locks are per-table.
        let res0 = c.run_lookup(&mut client, ObjectId(0), 9);
        assert!(!res0.locked);
    }

    #[test]
    fn rpc_only_tx_works() {
        let mut c = cluster(2, 1 << 8, 2);
        c.load(KV, 1..=50);
        let mut client = c.rpc_only_client();
        let out = c.run_tx(
            &mut client,
            vec![TxItem::read(KV, 10)],
            vec![TxItem::update(KV, 20)],
        );
        assert!(matches!(out, TxOutcome::Committed { .. }));
    }

    #[test]
    fn tx_stats_count_reads_and_rpcs() {
        let mut c = cluster(1, 1 << 8, 2);
        c.load(KV, 1..=10);
        let mut client = c.client(false);
        let mut tx = TxEngine::begin(600, vec![TxItem::read(KV, 2)], vec![TxItem::update(KV, 3)]);
        let posts = posts_of(tx.start(&mut client));
        let out = c.run_tx_posts(&mut client, &mut tx, posts);
        assert!(matches!(out, TxOutcome::Committed { .. }));
        // 1 execute read + 1 validation read; 1 lock RPC + 1 commit RPC.
        assert_eq!(tx.reads_issued, 2);
        assert_eq!(tx.rpcs_issued, 2);
    }

    fn replicated_cluster(nodes: u32) -> LocalCluster {
        let cat = CatalogConfig::new(vec![MicaConfig {
            buckets: 1 << 8,
            width: 2,
            value_len: 32,
            store_values: true,
        }])
        .with_replication(2);
        LocalCluster::new_hetero(nodes, cat)
    }

    #[test]
    fn replicated_commit_applies_on_backup_before_unlock() {
        let mut c = replicated_cluster(3);
        c.load(KV, 1..=60);
        let mut client = c.rpc_only_client();
        for key in 1..=60u64 {
            let out = c.run_tx(
                &mut client,
                vec![],
                vec![TxItem::update(KV, key).with_value(vec![0xAB; 32])],
            );
            assert!(matches!(out, TxOutcome::Committed { .. }), "key {key}");
        }
        // Every replica of every key carries the committed version and
        // value — the backup saw the write before the lock released.
        for key in 1..=60u64 {
            for node in c.replicas_of(key) {
                let (res, _) = c.nodes[node as usize].table(KV).get(key);
                match res {
                    RpcResult::Value { version, value, .. } => {
                        assert_eq!(version, 2, "key {key} node {node}");
                        assert_eq!(value.as_deref(), Some(&[0xAB; 32][..]));
                    }
                    other => panic!("key {key} missing on replica {node}: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn fenced_primary_refuses_and_expired_lease_promotes_backup() {
        let mut c = replicated_cluster(2);
        c.load(KV, 1..=40);
        let mut client = c.rpc_only_client();
        let key = (1..=40u64).find(|&k| c.replicas_of(k)[0] == 0).expect("a key primaried on 0");
        let backup = c.replicas_of(key)[1];
        assert_eq!(backup, 1);
        // Fence the primary: the write must abort with the typed reason.
        c.fence_node(0);
        let out = c.run_tx(&mut client, vec![], vec![TxItem::update(KV, key)]);
        assert_eq!(out, TxOutcome::Aborted(AbortReason::PrimaryFenced));
        // The client expires the lease; the retry routes to the backup
        // (client-observed promotion) and commits there alone.
        client.expire_lease(0);
        let out = c.run_tx(&mut client, vec![], vec![TxItem::update(KV, key)]);
        assert!(matches!(out, TxOutcome::Committed { .. }));
        match c.nodes[backup as usize].table(KV).get(key).0 {
            RpcResult::Value { version, locked, .. } => {
                assert_eq!(version, 2, "promoted backup applied the write");
                assert!(!locked);
            }
            other => panic!("unexpected {other:?}"),
        }
        // The fenced node still serves reads (fencing revokes write
        // authority, not data) but keeps its stale version.
        assert!(matches!(
            c.serve_rpc(0, &RpcRequest { obj: KV, key, op: RpcOp::Read, tx_id: 0, value: None })
                .result,
            RpcResult::Value { version: 1, .. }
        ));
    }

    #[test]
    fn recovery_rebuilds_tables_identical_to_survivors() {
        let mut c = replicated_cluster(3);
        c.load(KV, 1..=120);
        let mut client = c.rpc_only_client();
        // Mutate: updates bump versions, deletes remove, inserts add.
        for key in (1..=120u64).step_by(3) {
            let out = c.run_tx(
                &mut client,
                vec![],
                vec![TxItem::update(KV, key).with_value(vec![0xCD; 32])],
            );
            assert!(matches!(out, TxOutcome::Committed { .. }));
        }
        for key in (2..=120u64).step_by(7) {
            let out = c.run_tx(&mut client, vec![], vec![TxItem::delete(KV, key)]);
            assert!(matches!(out, TxOutcome::Committed { .. }));
        }
        for key in 200..=230u64 {
            let out = c.run_tx(&mut client, vec![], vec![TxItem::insert(KV, key)]);
            assert!(matches!(out, TxOutcome::Committed { .. }));
        }
        // Crash node 1 and rebuild it from its peers.
        c.recover_node(1);
        // Its table must hold exactly the keys whose replica chain
        // includes it, each byte-identical (key, version, value) to the
        // surviving replica.
        let mut rebuilt = c.nodes[1].table(KV).items();
        rebuilt.sort_by_key(|&(k, _, _)| k);
        for (key, version, value) in &rebuilt {
            let (key, version) = (*key, *version);
            assert!(c.replicas_of(key).contains(&1), "key {key} does not belong on node 1");
            let peer = *c.replicas_of(key).iter().find(|&&n| n != 1).expect("a surviving peer");
            match c.nodes[peer as usize].table(KV).get(key).0 {
                RpcResult::Value { version: pv, value: pval, .. } => {
                    assert_eq!(version, pv, "key {key}: version differs from survivor");
                    assert_eq!(value.as_deref(), pval.as_deref(), "key {key}: value differs");
                }
                other => panic!("survivor {peer} lost key {key}: {other:?}"),
            }
        }
        // And nothing it should hold is missing: count both directions.
        let expect: Vec<u64> = (1..=120u64)
            .chain(200..=230)
            .filter(|&k| !((2..=120).contains(&k) && (k - 2) % 7 == 0))
            .filter(|&k| c.replicas_of(k).contains(&1))
            .collect();
        assert_eq!(rebuilt.len(), expect.len(), "rebuilt key census");
        // A recovered node serves writes again.
        let key = expect[0];
        client.renew_lease(1);
        let out = c.run_tx(&mut client, vec![], vec![TxItem::update(KV, key)]);
        assert!(matches!(out, TxOutcome::Committed { .. }));
    }
}
