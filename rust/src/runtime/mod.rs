//! PJRT runtime: load and execute the AOT-compiled XLA artifacts.
//!
//! The L2 JAX graphs (`python/compile/model.py`, calling the L1 Pallas
//! kernels) are lowered **once** at build time to HLO *text* (see
//! `python/compile/aot.py`; text rather than serialized proto because
//! jax ≥ 0.5 emits 64-bit instruction ids the bundled xla_extension
//! rejects). This module compiles them on the PJRT CPU client at startup
//! and runs them from the Rust hot path — python never executes at
//! request time.
//!
//! Two executables make up Storm's batchable per-request compute:
//!
//! * `lookup_batch` — batched `lookup_start` address resolution: FNV-1a
//!   hash (the Pallas kernel), owner node, bucket index and byte offset
//!   for a batch of keys.
//! * `validate_batch` — batched OCC validation: compare observed
//!   (key, version, lock) triples against expectations.
//!
//! The live loopback dataplane calls these on its request path; `verify`
//! cross-checks them against the in-crate reference implementations
//! (`ds::mica::fnv1a64` et al.), which is the L1↔L3 correctness bridge.
//!
//! **Feature gate:** the PJRT backend needs the vendored `xla` bindings,
//! which exist only in the offline build image. Building with the `pjrt`
//! cargo feature selects them; without it (the default, and what CI
//! builds) a pure-Rust fallback [`Engine`] serves the identical API from
//! the reference implementations, so every driver, bench and example
//! still runs.

use std::path::Path;

use anyhow::{bail, Result};

use crate::ds::mica::{bucket_of, fnv1a64, owner_of};

/// Batch size the artifacts were exported with (see python/compile/aot.py).
pub const BATCH: usize = 64;

/// Result of batched lookup resolution for one key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resolved {
    /// Owner node.
    pub owner: u32,
    /// Bucket index within the owner's shard.
    pub bucket: u64,
    /// Byte offset of the bucket in the shard's region.
    pub offset: u64,
}

/// PJRT backend: compiles and executes the HLO artifacts via the vendored
/// `xla` bindings. Selected by the `pjrt` cargo feature.
#[cfg(feature = "pjrt")]
mod backend {
    use anyhow::{bail, Context};

    use super::*;

    /// The loaded executables.
    pub struct Engine {
        lookup: xla::PjRtLoadedExecutable,
        validate: xla::PjRtLoadedExecutable,
    }

    fn load_exe(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("loading HLO text from {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(client.compile(&comp)?)
    }

    impl Engine {
        /// Compile the artifacts in `dir` on the PJRT CPU client.
        pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
            let dir = dir.as_ref();
            let client = xla::PjRtClient::cpu()?;
            let lookup = load_exe(&client, &dir.join("lookup_batch.hlo.txt"))?;
            let validate = load_exe(&client, &dir.join("validate_batch.hlo.txt"))?;
            Ok(Engine { lookup, validate })
        }

        /// Batched `lookup_start`: resolve owners/buckets/offsets for up to
        /// [`BATCH`] keys (shorter slices are padded internally).
        pub fn lookup_resolve(
            &self,
            keys: &[u64],
            nodes: u32,
            bucket_mask: u64,
            bucket_bytes: u32,
        ) -> Result<Vec<Resolved>> {
            if keys.len() > BATCH {
                bail!("lookup_resolve batch too large: {} > {BATCH}", keys.len());
            }
            let mut padded = [0u64; BATCH];
            padded[..keys.len()].copy_from_slice(keys);
            let keys_lit = xla::Literal::vec1(&padded[..]);
            let nodes_lit = xla::Literal::scalar(nodes as u64);
            let mask_lit = xla::Literal::scalar(bucket_mask);
            let bb_lit = xla::Literal::scalar(bucket_bytes as u64);
            let result = self
                .lookup
                .execute::<xla::Literal>(&[keys_lit, nodes_lit, mask_lit, bb_lit])?[0][0]
                .to_literal_sync()?;
            let (owners, buckets, offsets) = result.to_tuple3()?;
            let owners = owners.to_vec::<u64>()?;
            let buckets = buckets.to_vec::<u64>()?;
            let offsets = offsets.to_vec::<u64>()?;
            Ok((0..keys.len())
                .map(|i| Resolved {
                    owner: owners[i] as u32,
                    bucket: buckets[i],
                    offset: offsets[i],
                })
                .collect())
        }

        /// Batched OCC validation: entry i passes when the observed key and
        /// version match the expectation and the item is unlocked.
        pub fn validate(
            &self,
            expect_keys: &[u64],
            observed_keys: &[u64],
            expect_versions: &[u64],
            observed_versions: &[u64],
            locked: &[u64],
        ) -> Result<Vec<bool>> {
            let n = expect_keys.len();
            if n > BATCH {
                bail!("validate batch too large: {n} > {BATCH}");
            }
            let pad = |src: &[u64]| {
                let mut p = [0u64; BATCH];
                p[..src.len()].copy_from_slice(src);
                xla::Literal::vec1(&p[..])
            };
            let result = self
                .validate
                .execute::<xla::Literal>(&[
                    pad(expect_keys),
                    pad(observed_keys),
                    pad(expect_versions),
                    pad(observed_versions),
                    pad(locked),
                ])?[0][0]
                .to_literal_sync()?;
            let ok = result.to_tuple1()?.to_vec::<u64>()?;
            Ok(ok[..n].iter().map(|&v| v != 0).collect())
        }
    }
}

/// Pure-Rust fallback backend: the same [`Engine`] API computed by the
/// in-crate reference implementations. Built when the `pjrt` feature is
/// off (CI, environments without the vendored xla runtime); the artifact
/// cross-check in `verify` then degenerates to a self-check, which is
/// stated in its output.
#[cfg(not(feature = "pjrt"))]
mod backend {
    use anyhow::bail;

    use super::*;

    /// Reference-backed engine (no PJRT available in this build).
    pub struct Engine;

    impl Engine {
        /// Accept any artifact directory; the fallback computes in-process.
        pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
            let _ = dir;
            Ok(Engine)
        }

        /// Batched `lookup_start`, computed by [`reference_resolve`].
        pub fn lookup_resolve(
            &self,
            keys: &[u64],
            nodes: u32,
            bucket_mask: u64,
            bucket_bytes: u32,
        ) -> Result<Vec<Resolved>> {
            if keys.len() > BATCH {
                bail!("lookup_resolve batch too large: {} > {BATCH}", keys.len());
            }
            Ok(keys
                .iter()
                .map(|&k| reference_resolve(k, nodes, bucket_mask, bucket_bytes))
                .collect())
        }

        /// Batched OCC validation, computed directly.
        pub fn validate(
            &self,
            expect_keys: &[u64],
            observed_keys: &[u64],
            expect_versions: &[u64],
            observed_versions: &[u64],
            locked: &[u64],
        ) -> Result<Vec<bool>> {
            let n = expect_keys.len();
            if n > BATCH {
                bail!("validate batch too large: {n} > {BATCH}");
            }
            Ok((0..n)
                .map(|i| {
                    expect_keys[i] == observed_keys[i]
                        && expect_versions[i] == observed_versions[i]
                        && locked[i] == 0
                })
                .collect())
        }
    }
}

pub use backend::Engine;

/// Which engine backend this build uses.
pub const BACKEND: &str = if cfg!(feature = "pjrt") { "pjrt" } else { "reference" };

/// Reference (pure-Rust) resolution — must agree with the artifacts.
pub fn reference_resolve(key: u64, nodes: u32, bucket_mask: u64, bucket_bytes: u32) -> Resolved {
    let bucket = bucket_of(key, bucket_mask);
    Resolved {
        owner: owner_of(key, nodes),
        bucket,
        offset: bucket * bucket_bytes as u64,
    }
}

/// Load the artifacts and cross-check them against the in-crate reference
/// implementation on a few thousand keys. This is the CI gate proving the
/// L1 Pallas kernel, the L2 JAX graph, and the L3 Rust reference all
/// compute the same function.
pub fn verify(dir: impl AsRef<Path>) -> Result<()> {
    let engine = Engine::load(&dir)?;
    let nodes = 16u32;
    let mask = (1u64 << 18) - 1;
    let bb = 128u32;
    let mut checked = 0usize;
    for base in (1u64..4096).step_by(BATCH) {
        let keys: Vec<u64> = (base..base + BATCH as u64)
            .map(|k| k.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            .collect();
        let got = engine.lookup_resolve(&keys, nodes, mask, bb)?;
        for (i, &key) in keys.iter().enumerate() {
            let want = reference_resolve(key, nodes, mask, bb);
            if got[i] != want {
                bail!("lookup mismatch for key {key:#x}: got {:?} want {want:?}", got[i]);
            }
            checked += 1;
        }
    }
    // Validation cross-check, including hash-derived pseudo versions.
    let keys: Vec<u64> = (1..=BATCH as u64).collect();
    let obs_keys: Vec<u64> =
        keys.iter().map(|&k| if k % 7 == 0 { k + 1 } else { k }).collect();
    let vers: Vec<u64> = keys.iter().map(|&k| fnv1a64(k) & 0xffff).collect();
    let obs_vers: Vec<u64> =
        vers.iter().enumerate().map(|(i, &v)| if i % 5 == 0 { v + 1 } else { v }).collect();
    let locked: Vec<u64> = keys.iter().map(|&k| (k % 11 == 0) as u64).collect();
    let ok = engine.validate(&keys, &obs_keys, &vers, &obs_vers, &locked)?;
    for i in 0..BATCH {
        let want = obs_keys[i] == keys[i] && obs_vers[i] == vers[i] && locked[i] == 0;
        if ok[i] != want {
            bail!("validate mismatch at {i}: got {} want {want}", ok[i]);
        }
        checked += 1;
    }
    println!("runtime verify OK ({BACKEND} backend): {checked} checks against 2 artifacts");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_resolve_matches_table_addressing() {
        let r = reference_resolve(42, 8, 0xff, 128);
        assert_eq!(r.owner, owner_of(42, 8));
        assert_eq!(r.bucket, bucket_of(42, 0xff));
        assert_eq!(r.offset, r.bucket * 128);
    }

    // Engine-backed tests live in rust/tests/runtime_artifacts.rs and run
    // only after `make artifacts` has produced the HLO files.
}
