//! Software (application-level) congestion control for the UD transport.
//!
//! RC offloads congestion control to the NIC (DCQCN/hardware CC) at zero
//! host cost — one of the paper's arguments for RC. UD systems such as
//! eRPC implement a Timely-style RTT-gradient rate controller in software;
//! this costs CPU per message *and* paces transmissions. eRPC's evaluation
//! (and this paper's Fig. 5) therefore includes a "no congestion control"
//! variant that runs ~1.5x faster at 16 nodes.
//!
//! The model here is a per-flow token-bucket rate limiter driven by a
//! simplified Timely update: the rate additively increases while sampled
//! RTTs stay below a low threshold, and multiplicatively decreases with
//! the RTT gradient above a high threshold. On the paper's uncongested
//! rack-scale runs the controller sits near its cap, so its visible costs
//! are (a) per-message CPU for bookkeeping and (b) pacing quantization —
//! both charged by the cluster simulator via [`AppCc::on_send`].

use crate::sim::Nanos;

/// Timely-like parameters.
#[derive(Clone, Copy, Debug)]
pub struct CcParams {
    /// Low RTT threshold: below this, additive increase (ns).
    pub t_low: Nanos,
    /// High RTT threshold: above this, multiplicative decrease (ns).
    pub t_high: Nanos,
    /// Additive increment (bytes/ns).
    pub add_step: f64,
    /// Multiplicative decrease factor weight.
    pub beta: f64,
    /// Minimum rate (bytes/ns).
    pub min_rate: f64,
    /// Line-rate cap (bytes/ns); 100 Gbps = 12.5 B/ns.
    pub max_rate: f64,
    /// CPU bookkeeping cost per send (timestamping, rate update) (ns).
    pub cpu_send_ns: u32,
    /// CPU bookkeeping per completion (RTT sample processing) (ns).
    pub cpu_ack_ns: u32,
}

impl Default for CcParams {
    fn default() -> Self {
        CcParams {
            t_low: 4_000,
            t_high: 12_000,
            add_step: 0.08,
            beta: 0.4,
            min_rate: 0.05,
            max_rate: 12.5,
            cpu_send_ns: 100,
            cpu_ack_ns: 80,
        }
    }
}

/// Per-destination-flow congestion control state.
#[derive(Clone, Debug)]
pub struct AppCc {
    params: CcParams,
    /// Current allowed rate (bytes/ns).
    rate: f64,
    /// Next instant the token bucket permits a send.
    next_send: Nanos,
    /// Last RTT sample (ns), for the gradient.
    prev_rtt: f64,
}

impl AppCc {
    /// New flow starting at half the cap (slow-start-ish but fast).
    pub fn new(params: CcParams) -> Self {
        AppCc { rate: params.max_rate * 0.5, next_send: 0, prev_rtt: 0.0, params }
    }

    /// Ask to send `bytes` at time `now`. Returns the pacing delay (0 when
    /// the bucket permits an immediate send) — the simulator schedules the
    /// actual transmission `delay` ns later and charges `cpu_send_ns`.
    pub fn on_send(&mut self, now: Nanos, bytes: u32) -> Nanos {
        let delay = self.next_send.saturating_sub(now);
        let start = now + delay;
        let tx_time = (bytes as f64 / self.rate).ceil() as Nanos;
        self.next_send = start + tx_time;
        delay
    }

    /// Feed an RTT sample (on response/ack receipt); updates the rate.
    pub fn on_ack(&mut self, rtt: Nanos) {
        let rtt = rtt as f64;
        let p = &self.params;
        if rtt < p.t_low as f64 {
            self.rate = (self.rate + p.add_step).min(p.max_rate);
        } else if rtt > p.t_high as f64 {
            let gradient = ((rtt - self.prev_rtt) / p.t_high as f64).clamp(0.0, 1.0);
            self.rate = (self.rate * (1.0 - p.beta * gradient)).max(p.min_rate);
        } else {
            // Between thresholds: gentle increase toward fairness.
            self.rate = (self.rate + p.add_step * 0.25).min(p.max_rate);
        }
        self.prev_rtt = rtt;
    }

    /// Current rate in bytes/ns.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// CPU cost charged per send.
    pub fn cpu_send_ns(&self) -> u32 {
        self.params.cpu_send_ns
    }

    /// CPU cost charged per ack/completion.
    pub fn cpu_ack_ns(&self) -> u32 {
        self.params.cpu_ack_ns
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn low_rtt_grows_rate_to_cap() {
        let mut cc = AppCc::new(CcParams::default());
        for _ in 0..1000 {
            cc.on_ack(2_000);
        }
        assert!((cc.rate() - CcParams::default().max_rate).abs() < 0.1);
    }

    #[test]
    fn high_rtt_cuts_rate() {
        let mut cc = AppCc::new(CcParams::default());
        let before = cc.rate();
        cc.on_ack(40_000);
        cc.on_ack(80_000); // rising gradient
        assert!(cc.rate() < before);
        // Never below the floor.
        for _ in 0..200 {
            cc.on_ack(1_000_000);
        }
        assert!(cc.rate() >= CcParams::default().min_rate);
    }

    #[test]
    fn pacing_spaces_sends() {
        let mut cc = AppCc::new(CcParams::default());
        // rate = 6.25 B/ns initially; a 6250-byte send occupies 1000 ns.
        let d0 = cc.on_send(0, 6250);
        assert_eq!(d0, 0);
        let d1 = cc.on_send(0, 6250);
        assert_eq!(d1, 1000);
        let d2 = cc.on_send(2000, 6250); // bucket already drained by then
        assert_eq!(d2, 0);
    }

    #[test]
    fn small_messages_barely_pace_at_high_rate() {
        let mut cc = AppCc::new(CcParams::default());
        for _ in 0..1000 {
            cc.on_ack(1_000); // drive to cap
        }
        // 128 B at 12.5 B/ns ~ 11 ns between sends: offering a send every
        // 12 ns must never be paced.
        let mut total = 0;
        for t in 0..100u64 {
            total += cc.on_send(t * 12, 128);
        }
        assert_eq!(total, 0, "pacing too aggressive");
        // Offering faster than the line rate (every 5 ns) must be paced.
        let mut paced = 0;
        for t in 0..100u64 {
            paced += cc.on_send(1_000_000 + t * 5, 128);
        }
        assert!(paced > 0);
    }
}
