//! Transport layer: queue pairs, connection topology, congestion control.
//!
//! Storm's design principle #2 is *leverage RC connections*: one RC
//! connection per **sibling thread pair** and per data path (remote reads
//! vs. RPCs) — `2·m·t` connections per machine — with retransmission and
//! congestion control offloaded to the NIC. The UD transport (used by the
//! eRPC baseline) gets one QP per thread but needs software congestion
//! control, software retransmission, and receive-queue management.
//!
//! This module owns the *identity and policy* side: connection id algebra
//! ([`topology`]), software congestion control ([`cc`]), and UD receive
//! pools/retransmission ([`ud`]). The *timing* side (what each verb costs
//! at each NIC) lives in [`crate::nic`]; the event flow lives in
//! [`crate::cluster`].

pub mod cc;
pub mod topology;
pub mod ud;

pub use cc::AppCc;
pub use topology::{Channel, ConnId, Topology};
pub use ud::{RecvPool, RetransmitState};
