//! Transport layer: queue pairs, topology, congestion control, and the
//! adaptive per-destination path decision.
//!
//! Storm's design principle #2 is *leverage RC connections*: one RC
//! connection per **sibling thread pair** and per data path (remote reads
//! vs. RPCs) — `2·m·t` connections per machine — with retransmission and
//! congestion control offloaded to the NIC. The UD transport (used by the
//! eRPC baseline) gets one QP per thread but needs software congestion
//! control, software retransmission, and receive-queue management.
//!
//! That static dichotomy is where the seed stopped. This module now owns
//! the *choice* as well, per destination and at runtime:
//!
//! * [`topology`] — the connection-id algebra: sibling-pair RC mesh,
//!   Fig. 7 `conn_multiplier` striping, and `qp_share` multiplexing where
//!   groups of sibling threads share one RC connection per (pair, channel)
//!   to shrink the NIC's QP working set (RDMAvisor's thesis).
//! * [`adaptive`] — the per-destination degradation state machine. Each
//!   client node watches the modeled NIC cache in 50 µs epochs and demotes
//!   cold/thrashing destinations from RC to UD (paying the [`ud`] receive
//!   pool and [`cc`] software-CC costs), promoting them back on re-warm,
//!   with exponential per-destination cooldown so transitions are bounded.
//! * [`cc`] / [`ud`] — the costs the demoted path pays: software
//!   congestion control, receive-pool reposts, and timeout retransmission.
//!   These are shared by the eRPC baseline and the adaptive path.
//!
//! The *timing* side (what each verb costs at each NIC) lives in
//! [`crate::nic`]; the event flow lives in [`crate::cluster`].

pub mod adaptive;
pub mod cc;
pub mod topology;
pub mod ud;

pub use adaptive::{PathChoice, Transport, TransportPolicy};
pub use cc::AppCc;
pub use topology::{Channel, ConnId, Topology};
pub use ud::{RecvPool, RetransmitState};
