//! Connection topology: Storm's sibling-pair RC mesh, UD QPs, and QP sharing.
//!
//! Global connection ids are deterministic functions of the endpoints so
//! both NICs charge their caches against the same id, and tests can reason
//! about the id algebra. The Fig. 7 cluster-emulation trick ("creating
//! additional connections and allocating additional buffers between each
//! pair of machines") is the `conn_multiplier`: every (pair, thread,
//! channel) gets `k` parallel connections and senders stripe across them,
//! inflating the NIC's QP working set exactly the way the paper's emulation
//! does.
//!
//! `qp_share` goes the other way (RDMAvisor's thesis): groups of `s`
//! sibling threads share one RC connection per (pair, channel), shrinking
//! the QP working set by `s` at the price of a software lock on the shared
//! send queue. Sharing and striping compose: ids are derived from the
//! *thread group* (`thread / qp_share`), so the algebra stays collision-free
//! across (pair, group, channel, lane) and both endpoints of a sibling pair
//! still derive the same id.

/// Global connection (QP) identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ConnId(pub u64);

/// Storm separates one-sided reads and RPC traffic onto distinct QPs
/// (its "two independent data paths", Fig. 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Channel {
    /// One-sided remote reads (and validation reads).
    ReadPath = 0,
    /// Write-based RPCs.
    RpcPath = 1,
}

/// Cluster connection topology.
#[derive(Clone, Debug)]
pub struct Topology {
    /// Physical machines.
    pub nodes: u32,
    /// Threads per machine (sibling sets).
    pub threads: u32,
    /// Parallel connections per (pair, thread, channel) — 1 normally, >1
    /// when emulating a larger cluster (Fig. 7).
    pub conn_multiplier: u32,
    /// Threads sharing one RC connection per (pair, channel) — 1 normally
    /// (every sibling pair gets its own QP), >1 to multiplex.
    pub qp_share: u32,
}

impl Topology {
    /// Standard topology.
    pub fn new(nodes: u32, threads: u32) -> Self {
        Topology { nodes, threads, conn_multiplier: 1, qp_share: 1 }
    }

    /// Topology emulating `virtual_nodes` on `nodes` physical machines.
    pub fn emulated(nodes: u32, threads: u32, virtual_nodes: u32) -> Self {
        assert!(virtual_nodes >= nodes && virtual_nodes % nodes == 0);
        Topology { nodes, threads, conn_multiplier: virtual_nodes / nodes, qp_share: 1 }
    }

    /// Thread groups per machine under QP sharing (ceiling division so a
    /// ragged last group still gets a connection).
    pub fn thread_groups(&self) -> u32 {
        let s = self.qp_share.max(1);
        (self.threads + s - 1) / s
    }

    /// RC connection between sibling threads `thread` of `a` and `b`, on
    /// `channel`, stripe `lane < conn_multiplier`. With `qp_share > 1` the
    /// id is derived from the thread *group*, so all threads in a group map
    /// to the same shared connection.
    pub fn rc_conn(&self, a: u32, b: u32, thread: u32, channel: Channel, lane: u32) -> ConnId {
        assert!(a != b, "no self-connections");
        assert!(thread < self.threads && lane < self.conn_multiplier);
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        let n = self.nodes as u64;
        let pair = lo as u64 * n + hi as u64;
        let group = (thread / self.qp_share.max(1)) as u64;
        let id = ((pair * self.thread_groups() as u64 + group) * 2 + channel as u64)
            * self.conn_multiplier as u64
            + lane as u64;
        ConnId(id)
    }

    /// UD QP of (`node`, `thread`) — one per thread, distinct id space
    /// (top bit set).
    pub fn ud_qp(&self, node: u32, thread: u32) -> ConnId {
        ConnId((1 << 63) | ((node as u64) * self.threads as u64 + thread as u64))
    }

    /// RC connections terminating at each machine: the paper's `2·m·t`
    /// (× multiplier when emulating, ÷ share factor when multiplexing).
    pub fn rc_conns_per_machine(&self) -> u64 {
        2 * (self.nodes as u64 - 1) * self.thread_groups() as u64 * self.conn_multiplier as u64
    }

    /// Bytes of QP context a NIC must cache when all its connections are
    /// active.
    pub fn qp_state_bytes_per_machine(&self) -> u64 {
        self.rc_conns_per_machine() * crate::mem::region::entry_sizes::QP_CONTEXT
    }

    /// The virtual cluster size this topology emulates.
    pub fn virtual_nodes(&self) -> u32 {
        self.nodes * self.conn_multiplier
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conn_ids_symmetric() {
        let t = Topology::new(8, 4);
        let ab = t.rc_conn(2, 5, 3, Channel::ReadPath, 0);
        let ba = t.rc_conn(5, 2, 3, Channel::ReadPath, 0);
        assert_eq!(ab, ba);
    }

    #[test]
    fn conn_ids_unique() {
        let t = Topology::emulated(4, 3, 8);
        let mut seen = std::collections::HashSet::new();
        for a in 0..4 {
            for b in 0..4 {
                if a == b {
                    continue;
                }
                for th in 0..3 {
                    for ch in [Channel::ReadPath, Channel::RpcPath] {
                        for lane in 0..2 {
                            seen.insert(t.rc_conn(a, b, th, ch, lane));
                        }
                    }
                }
            }
        }
        // pairs = 6, x threads 3 x channels 2 x lanes 2 = 72 distinct.
        assert_eq!(seen.len(), 72);
    }

    #[test]
    fn channels_are_distinct_qps() {
        let t = Topology::new(4, 2);
        assert_ne!(
            t.rc_conn(0, 1, 0, Channel::ReadPath, 0),
            t.rc_conn(0, 1, 0, Channel::RpcPath, 0)
        );
    }

    #[test]
    fn ud_ids_disjoint_from_rc() {
        let t = Topology::new(16, 20);
        let ud = t.ud_qp(3, 7);
        for a in 0..4 {
            for b in (a + 1)..4 {
                assert_ne!(t.rc_conn(a, b, 0, Channel::ReadPath, 0), ud);
            }
        }
    }

    #[test]
    fn paper_connection_count_formula() {
        // Paper: 2 x m x t connections per machine (m=32, t=20 -> 1280ish).
        let t = Topology::new(32, 20);
        assert_eq!(t.rc_conns_per_machine(), 2 * 31 * 20);
        // QP state: ~465 KB at 32 nodes — comfortably inside a 2 MB cache.
        assert!(t.qp_state_bytes_per_machine() < 2 << 20);
        // At an emulated 96 nodes x 20 threads it exceeds half the cache and
        // starts competing with MTT/MPT/WQE state (the Fig. 7 drop).
        let big = Topology::emulated(32, 20, 96);
        assert!(big.qp_state_bytes_per_machine() > 1 << 20);
        assert_eq!(big.virtual_nodes(), 96);
    }

    #[test]
    fn emulation_multiplies_lanes() {
        let t = Topology::emulated(32, 10, 128);
        assert_eq!(t.conn_multiplier, 4);
        assert_eq!(t.rc_conns_per_machine(), 2 * 31 * 10 * 4);
    }

    #[test]
    fn qp_share_collapses_sibling_threads() {
        let mut t = Topology::new(8, 8);
        t.qp_share = 4;
        assert_eq!(t.thread_groups(), 2);
        // Threads 0..3 share one connection, 4..7 share another.
        let a = t.rc_conn(1, 2, 0, Channel::ReadPath, 0);
        assert_eq!(a, t.rc_conn(1, 2, 3, Channel::ReadPath, 0));
        let b = t.rc_conn(1, 2, 4, Channel::ReadPath, 0);
        assert_eq!(b, t.rc_conn(1, 2, 7, Channel::ReadPath, 0));
        assert_ne!(a, b);
        // Connection count shrinks by the share factor.
        assert_eq!(t.rc_conns_per_machine(), 2 * 7 * 2);
    }

    #[test]
    fn qp_share_ragged_group_still_connected() {
        let mut t = Topology::new(4, 5);
        t.qp_share = 2;
        assert_eq!(t.thread_groups(), 3);
        // Thread 4 is alone in the last group but still has a valid id.
        let lone = t.rc_conn(0, 1, 4, Channel::RpcPath, 0);
        assert_ne!(lone, t.rc_conn(0, 1, 3, Channel::RpcPath, 0));
    }

    #[test]
    fn qp_share_one_matches_unshared_algebra() {
        let base = Topology::new(6, 4);
        let mut shared = Topology::new(6, 4);
        shared.qp_share = 1;
        for th in 0..4 {
            for ch in [Channel::ReadPath, Channel::RpcPath] {
                assert_eq!(base.rc_conn(0, 3, th, ch, 0), shared.rc_conn(0, 3, th, ch, 0));
            }
        }
    }
}
