//! Adaptive per-destination transport selection (RC → UD degradation).
//!
//! The paper's Fig. 7 shows one-sided RC holding up to thousands of
//! connections — but past the NIC's SRAM state cache the per-QP context
//! starts thrashing and throughput collapses toward the Fig. 1 cliff. The
//! classic escape hatch is the eRPC/FaSST position: drop to UD datagrams,
//! whose connection state is O(threads) instead of O(cluster), and pay for
//! it in CPU (receive-pool reposts, software congestion control and
//! retransmission, per-frame header handling).
//!
//! `Transport` makes that trade *per destination* at runtime instead of
//! globally at configuration time. Each client node runs one controller
//! that watches the modeled NIC cache (cumulative hit/miss counters plus a
//! per-packet "cold" signal: the send missed its QP context or hot send
//! slot) in fixed 50 µs epochs:
//!
//! * **Demote** — when an epoch's cache hit-rate falls below [`LOW_HIT`]
//!   and a destination's sends were mostly cold for [`HYSTERESIS_EPOCHS`]
//!   consecutive epochs (with at least [`MIN_SAMPLES`] sends accumulated
//!   over the streak), its RC connections are abandoned and traffic is
//!   redirected to the thread's UD QP. Coldest destinations go first, at
//!   most [`MAX_DEMOTIONS_PER_EPOCH`] per epoch, so one bad epoch cannot
//!   flip the whole fan-out.
//! * **Promote** — when the cache re-warms (hit-rate above [`HIGH_HIT`]
//!   for [`HYSTERESIS_EPOCHS`] epochs), the busiest demoted destination is
//!   returned to RC, one per epoch. Demotion itself relieves the cache, so
//!   the controller often settles *between* the two thresholds; after
//!   [`PROBE_EPOCHS`] of stable (≥ [`LOW_HIT`]) behaviour it promotes one
//!   destination as a probe — the only way a re-warmed cache is ever
//!   rediscovered from inside the hysteresis band.
//! * **No flapping** — every transition starts a per-destination cooldown
//!   that doubles with each subsequent transition (exponential backoff,
//!   capped), so the total transition count is bounded regardless of how
//!   adversarial the load is.
//!
//! The controller is deliberately independent of the NIC model types: the
//! world feeds it plain counters, and tests can drive it synthetically.

/// Transport selection policy for a simulation run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportPolicy {
    /// Always RC (the seed behaviour for Storm-family systems).
    StaticRc,
    /// Always UD (every remote op pays the datagram CPU costs).
    StaticUd,
    /// Per-destination RC with degradation to UD under NIC-cache pressure.
    Adaptive,
}

/// The path a particular send should take.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PathChoice {
    /// Reliable-connected QP (one-sided reads + RC sends).
    Rc,
    /// Unreliable datagram QP (software CC + retransmission + recv pool).
    Ud,
}

/// Controller epoch length. Matches the NIC model's active-QP window so
/// hit-rate deltas line up with what `Nic::active_qps` reports.
pub const EPOCH_NS: u64 = 50_000;
/// Epoch hit-rate below which demotion is considered.
pub const LOW_HIT: f64 = 0.70;
/// Epoch hit-rate above which promotion is considered.
pub const HIGH_HIT: f64 = 0.90;
/// Consecutive qualifying epochs required before a transition.
pub const HYSTERESIS_EPOCHS: u32 = 2;
/// Minimum sends accumulated over a destination's current cold streak
/// before it may be demoted. Accumulating across epochs (rather than
/// requiring the floor within a single epoch) matters at rack scale: a
/// 256-way fan-out spreads an epoch's traffic so thin that no single
/// destination sees many sends, yet the cold evidence is just as real.
pub const MIN_SAMPLES: u32 = 8;
/// Fraction of a destination's sends that must be cold in an epoch.
pub const COLD_RATE: f64 = 0.5;
/// Cap on demotions per epoch (coldest first).
pub const MAX_DEMOTIONS_PER_EPOCH: usize = 4;
/// Cooldown after a transition, in epochs; doubles per transition (capped).
pub const COOLDOWN_BASE_EPOCHS: u64 = 4;
/// Consecutive stable (hit-rate ≥ [`LOW_HIT`]) epochs after which one
/// demoted destination is probed back onto RC even though the cache never
/// crossed [`HIGH_HIT`]. Probing is what discovers re-warm from inside the
/// hysteresis band; flapping stays bounded because each transition doubles
/// the per-destination cooldown.
pub const PROBE_EPOCHS: u32 = 16;

#[derive(Clone, Copy, Debug, Default)]
struct DestState {
    /// Currently demoted to UD?
    demoted: bool,
    /// Sends this epoch.
    sends: u32,
    /// Cold sends (QP context / send-slot miss) this epoch.
    cold: u32,
    /// Consecutive epochs the destination qualified as cold.
    cold_epochs: u32,
    /// Sends accumulated over the current cold streak (sample floor).
    streak_sends: u32,
    /// Consecutive re-warm epochs (demoted destinations only).
    warm_epochs: u32,
    /// Lifetime transitions, drives exponential cooldown.
    transitions: u32,
    /// Epoch index before which no further transition is allowed.
    cooldown_until: u64,
}

/// Per-client-node adaptive transport controller.
#[derive(Clone, Debug)]
pub struct Transport {
    policy: TransportPolicy,
    dests: Vec<DestState>,
    epoch: u64,
    prev_hits: u64,
    prev_misses: u64,
    /// Consecutive epochs with hit-rate ≥ [`LOW_HIT`] (drives probing).
    stable_epochs: u32,
    demotions: u64,
    promotions: u64,
}

impl Transport {
    /// Controller for one client node talking to `dests` destinations.
    pub fn new(policy: TransportPolicy, dests: u32) -> Self {
        Transport {
            policy,
            dests: vec![DestState::default(); dests as usize],
            epoch: 0,
            prev_hits: 0,
            prev_misses: 0,
            stable_epochs: 0,
            demotions: 0,
            promotions: 0,
        }
    }

    /// Path for the next send to `dest`.
    pub fn choose(&self, dest: u32) -> PathChoice {
        match self.policy {
            TransportPolicy::StaticRc => PathChoice::Rc,
            TransportPolicy::StaticUd => PathChoice::Ud,
            TransportPolicy::Adaptive => {
                if self.dests[dest as usize].demoted {
                    PathChoice::Ud
                } else {
                    PathChoice::Rc
                }
            }
        }
    }

    /// Record an outbound request. `cold` means the NIC paid a QP-context
    /// or hot-slot miss for it; `cache_hits`/`cache_misses` are the NIC
    /// cache's *cumulative* counters, from which the controller derives
    /// per-epoch deltas. Rolls the epoch lazily off the packet clock.
    pub fn on_tx(&mut self, now: u64, dest: u32, cold: bool, cache_hits: u64, cache_misses: u64) {
        if self.policy != TransportPolicy::Adaptive {
            return;
        }
        let idx = now / EPOCH_NS;
        if idx > self.epoch {
            self.roll_epoch(idx, cache_hits, cache_misses);
        }
        let d = &mut self.dests[dest as usize];
        d.sends += 1;
        if cold {
            d.cold += 1;
        }
    }

    /// Finalize the current epoch against cumulative cache counters and
    /// apply demotion/promotion decisions. Public so the controller can be
    /// driven synthetically in tests.
    pub fn roll_epoch(&mut self, next_epoch: u64, cache_hits: u64, cache_misses: u64) {
        let dh = cache_hits.saturating_sub(self.prev_hits);
        let dm = cache_misses.saturating_sub(self.prev_misses);
        self.prev_hits = cache_hits;
        self.prev_misses = cache_misses;
        let hit_rate = if dh + dm == 0 { 1.0 } else { dh as f64 / (dh + dm) as f64 };

        // Update per-destination streaks.
        for d in self.dests.iter_mut() {
            if !d.demoted {
                let was_cold = d.sends > 0 && d.cold as f64 >= COLD_RATE * d.sends as f64;
                if was_cold {
                    d.cold_epochs += 1;
                    d.streak_sends = d.streak_sends.saturating_add(d.sends);
                } else if d.sends > 0 {
                    d.cold_epochs = 0;
                    d.streak_sends = 0;
                }
            } else if hit_rate >= LOW_HIT {
                d.warm_epochs += 1;
            } else {
                d.warm_epochs = 0;
            }
        }
        if hit_rate >= LOW_HIT {
            self.stable_epochs += 1;
        } else {
            self.stable_epochs = 0;
        }

        if hit_rate < LOW_HIT {
            self.demote_coldest();
        } else if hit_rate >= HIGH_HIT {
            self.promote_busiest();
        } else if self.stable_epochs >= PROBE_EPOCHS {
            // Stuck in the hysteresis band: demotion relieved the cache
            // enough that neither threshold fires. Probe one destination
            // back onto RC to test whether the cache can absorb it.
            self.promote_busiest();
            self.stable_epochs = 0;
        }

        for d in self.dests.iter_mut() {
            d.sends = 0;
            d.cold = 0;
        }
        self.epoch = next_epoch;
    }

    fn demote_coldest(&mut self) {
        let epoch = self.epoch;
        let mut cands: Vec<(u32, usize)> = Vec::new();
        for (i, d) in self.dests.iter().enumerate() {
            if !d.demoted
                && d.cold_epochs >= HYSTERESIS_EPOCHS
                && d.streak_sends >= MIN_SAMPLES
                && d.cooldown_until <= epoch
            {
                cands.push((d.cold, i));
            }
        }
        // Coldest (most cold sends this epoch) first; index breaks ties
        // deterministically.
        cands.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        for &(_, i) in cands.iter().take(MAX_DEMOTIONS_PER_EPOCH) {
            let d = &mut self.dests[i];
            d.demoted = true;
            d.cold_epochs = 0;
            d.streak_sends = 0;
            d.warm_epochs = 0;
            d.transitions += 1;
            d.cooldown_until = epoch + (COOLDOWN_BASE_EPOCHS << (d.transitions.min(6) as u64));
            self.demotions += 1;
        }
    }

    fn promote_busiest(&mut self) {
        let epoch = self.epoch;
        let mut best: Option<(u32, usize)> = None;
        for (i, d) in self.dests.iter().enumerate() {
            if d.demoted && d.warm_epochs >= HYSTERESIS_EPOCHS && d.cooldown_until <= epoch {
                let better = match best {
                    None => true,
                    Some((s, _)) => d.sends > s,
                };
                if better {
                    best = Some((d.sends, i));
                }
            }
        }
        if let Some((_, i)) = best {
            let d = &mut self.dests[i];
            d.demoted = false;
            d.cold_epochs = 0;
            d.streak_sends = 0;
            d.warm_epochs = 0;
            d.transitions += 1;
            d.cooldown_until = epoch + (COOLDOWN_BASE_EPOCHS << (d.transitions.min(6) as u64));
            self.promotions += 1;
        }
    }

    /// Lifetime RC→UD demotions on this node.
    pub fn demotions(&self) -> u64 {
        self.demotions
    }

    /// Lifetime UD→RC promotions on this node.
    pub fn promotions(&self) -> u64 {
        self.promotions
    }

    /// Destinations currently served over UD.
    pub fn ud_destinations(&self) -> u32 {
        self.dests.iter().filter(|d| d.demoted).count() as u32
    }

    /// The policy this controller runs.
    pub fn policy(&self) -> TransportPolicy {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drive one epoch: `sends` packets to each dest in `cold_dests`
    /// flagged cold, then roll with the given cumulative counters.
    fn drive_epoch(
        t: &mut Transport,
        epoch: &mut u64,
        cold_dests: &[u32],
        warm_dests: &[u32],
        hits: &mut u64,
        misses: &mut u64,
        cache_cold: bool,
    ) {
        for &d in cold_dests {
            for _ in 0..MIN_SAMPLES {
                t.on_tx(*epoch * EPOCH_NS, d, true, *hits, *misses);
            }
        }
        for &d in warm_dests {
            for _ in 0..MIN_SAMPLES {
                t.on_tx(*epoch * EPOCH_NS, d, false, *hits, *misses);
            }
        }
        if cache_cold {
            *misses += 600;
            *hits += 400; // 40% hit rate — well under LOW_HIT
        } else {
            *hits += 1000; // ~100% — above HIGH_HIT
        }
        *epoch += 1;
        t.roll_epoch(*epoch, *hits, *misses);
    }

    #[test]
    fn static_policies_never_transition() {
        for policy in [TransportPolicy::StaticRc, TransportPolicy::StaticUd] {
            let mut t = Transport::new(policy, 8);
            for e in 0..20u64 {
                t.on_tx(e * EPOCH_NS, 3, true, 0, e * 100);
            }
            assert_eq!(t.demotions() + t.promotions(), 0);
            let want = if policy == TransportPolicy::StaticUd {
                PathChoice::Ud
            } else {
                PathChoice::Rc
            };
            assert_eq!(t.choose(3), want);
        }
    }

    #[test]
    fn cold_epochs_demote_then_rewarm_promotes() {
        let mut t = Transport::new(TransportPolicy::Adaptive, 4);
        let (mut epoch, mut hits, mut misses) = (0u64, 0u64, 0u64);
        // Dest 2 thrashes while the cache is cold.
        for _ in 0..4 {
            drive_epoch(&mut t, &mut epoch, &[2], &[0, 1], &mut hits, &mut misses, true);
        }
        assert_eq!(t.choose(2), PathChoice::Ud, "cold dest demoted");
        assert_eq!(t.choose(0), PathChoice::Rc, "warm dest untouched");
        assert_eq!(t.demotions(), 1);
        assert_eq!(t.ud_destinations(), 1);
        // Cache re-warms: after cooldown + hysteresis, dest 2 comes back.
        for _ in 0..40 {
            drive_epoch(&mut t, &mut epoch, &[], &[0, 1, 2], &mut hits, &mut misses, false);
        }
        assert_eq!(t.choose(2), PathChoice::Rc, "re-warmed dest promoted");
        assert_eq!(t.promotions(), 1);
        assert_eq!(t.ud_destinations(), 0);
    }

    #[test]
    fn probe_promotes_from_inside_the_hysteresis_band() {
        let mut t = Transport::new(TransportPolicy::Adaptive, 4);
        let (mut epoch, mut hits, mut misses) = (0u64, 0u64, 0u64);
        // Demote dest 3 while the cache is cold...
        for _ in 0..4 {
            drive_epoch(&mut t, &mut epoch, &[3], &[0, 1], &mut hits, &mut misses, true);
        }
        assert_eq!(t.choose(3), PathChoice::Ud);
        // ...then hold the hit-rate between LOW_HIT and HIGH_HIT: the
        // immediate-promotion path never qualifies, but the probe must
        // eventually return dest 3 to RC.
        for _ in 0..(PROBE_EPOCHS * 3) {
            for d in [0u32, 1] {
                for _ in 0..MIN_SAMPLES {
                    t.on_tx(epoch * EPOCH_NS, d, false, hits, misses);
                }
            }
            hits += 800;
            misses += 200; // 80% — inside the hysteresis band
            epoch += 1;
            t.roll_epoch(epoch, hits, misses);
        }
        assert_eq!(t.choose(3), PathChoice::Rc, "probe must rediscover re-warm");
        assert!(t.promotions() >= 1);
    }

    #[test]
    fn transitions_are_bounded_under_oscillation() {
        let mut t = Transport::new(TransportPolicy::Adaptive, 2);
        let (mut epoch, mut hits, mut misses) = (0u64, 0u64, 0u64);
        // Adversarial load: alternate cold and warm phases forever.
        for phase in 0..200 {
            let cold = phase % 2 == 0;
            for _ in 0..3 {
                let (c, w): (&[u32], &[u32]) = if cold { (&[1], &[0]) } else { (&[], &[0, 1]) };
                drive_epoch(&mut t, &mut epoch, c, w, &mut hits, &mut misses, cold);
            }
        }
        // Exponential cooldown keeps the flap count tiny relative to the
        // 600 epochs simulated.
        assert!(
            t.demotions() + t.promotions() <= 16,
            "flapping: {} transitions",
            t.demotions() + t.promotions()
        );
    }

    #[test]
    fn demotions_capped_per_epoch_and_coldest_first() {
        let mut t = Transport::new(TransportPolicy::Adaptive, 16);
        let (mut epoch, mut hits, mut misses) = (0u64, 0u64, 0u64);
        let all: Vec<u32> = (0..16).collect();
        for _ in 0..HYSTERESIS_EPOCHS {
            drive_epoch(&mut t, &mut epoch, &all, &[], &mut hits, &mut misses, true);
        }
        assert_eq!(t.demotions() as usize, MAX_DEMOTIONS_PER_EPOCH);
    }

    #[test]
    fn warm_destination_never_demoted() {
        let mut t = Transport::new(TransportPolicy::Adaptive, 4);
        let (mut epoch, mut hits, mut misses) = (0u64, 0u64, 0u64);
        // Cache is cold overall but dest 0's sends all hit.
        for _ in 0..10 {
            drive_epoch(&mut t, &mut epoch, &[], &[0], &mut hits, &mut misses, true);
        }
        assert_eq!(t.choose(0), PathChoice::Rc);
        assert_eq!(t.demotions(), 0);
    }
}
