//! UD reliability machinery: receive pools and software retransmission.
//!
//! UD is unreliable: if a datagram arrives and the receive queue has no
//! posted buffer, it is silently dropped and the *application* must detect
//! the loss by timeout and retransmit (RC offloads all of this to the NIC).
//! eRPC therefore keeps large pools of pre-posted receive buffers — which
//! is exactly what limited the paper's eRPC deployment to 16 nodes ("our
//! NICs do not support sufficiently large receive queues", fixable only
//! with strided RQs they didn't have).

use crate::sim::Nanos;

/// A receive-buffer pool shared by one machine's UD QPs.
#[derive(Clone, Debug)]
pub struct RecvPool {
    capacity: u32,
    posted: u32,
    /// Buffers consumed but not yet reposted by the host.
    pending_repost: u32,
    drops: u64,
    delivered: u64,
}

impl RecvPool {
    /// Pool with `capacity` posted buffers (the NIC's RQ depth limit).
    pub fn new(capacity: u32) -> Self {
        RecvPool { capacity, posted: capacity, pending_repost: 0, drops: 0, delivered: 0 }
    }

    /// An inbound datagram arrives: consume a buffer, or drop.
    /// Returns `true` when delivered.
    pub fn arrive(&mut self) -> bool {
        if self.posted == 0 {
            self.drops += 1;
            return false;
        }
        self.posted -= 1;
        self.pending_repost += 1;
        self.delivered += 1;
        true
    }

    /// Host reposts up to `batch` consumed buffers; returns how many were
    /// actually reposted (CPU cost is charged by the caller per buffer).
    pub fn repost(&mut self, batch: u32) -> u32 {
        let n = batch.min(self.pending_repost);
        self.pending_repost -= n;
        self.posted += n;
        debug_assert!(self.posted <= self.capacity);
        n
    }

    /// Buffers currently posted.
    pub fn posted(&self) -> u32 {
        self.posted
    }

    /// Datagrams dropped for lack of buffers.
    pub fn drops(&self) -> u64 {
        self.drops
    }

    /// Datagrams delivered.
    pub fn delivered(&self) -> u64 {
        self.delivered
    }

    /// Can this pool provision `peers` remote senders with `window`
    /// outstanding messages each? (The paper's 16-node eRPC limit.)
    pub fn can_provision(&self, peers: u32, window: u32) -> bool {
        peers.saturating_mul(window) <= self.capacity
    }
}

/// Software retransmission state for one outstanding UD request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetransmitState {
    /// Retransmission timeout.
    pub rto: Nanos,
    /// Deadline after which the request is considered lost.
    pub deadline: Nanos,
    /// Retries so far.
    pub retries: u32,
    /// Give up after this many retries.
    pub max_retries: u32,
}

impl RetransmitState {
    /// Arm a timer for a request sent at `now`.
    pub fn armed(now: Nanos, rto: Nanos, max_retries: u32) -> Self {
        RetransmitState { rto, deadline: now + rto, retries: 0, max_retries }
    }

    /// Timer fired at `now` without a response: decide to retry (with
    /// exponential backoff) or give up.
    pub fn on_timeout(&mut self, now: Nanos) -> RetransmitDecision {
        if self.retries >= self.max_retries {
            return RetransmitDecision::GiveUp;
        }
        self.retries += 1;
        self.rto = self.rto.saturating_mul(2);
        self.deadline = now + self.rto;
        RetransmitDecision::Retry
    }
}

/// Outcome of a retransmission timeout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RetransmitDecision {
    /// Send the request again; timer re-armed.
    Retry,
    /// Too many retries; fail the op upward.
    GiveUp,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_delivers_until_empty_then_drops() {
        let mut p = RecvPool::new(2);
        assert!(p.arrive());
        assert!(p.arrive());
        assert!(!p.arrive(), "third datagram must drop");
        assert_eq!(p.drops(), 1);
        assert_eq!(p.delivered(), 2);
    }

    #[test]
    fn repost_restores_capacity() {
        let mut p = RecvPool::new(4);
        for _ in 0..4 {
            p.arrive();
        }
        assert_eq!(p.posted(), 0);
        assert_eq!(p.repost(8), 4, "only consumed buffers repostable");
        assert_eq!(p.posted(), 4);
        assert!(p.arrive());
    }

    #[test]
    fn provisioning_check_matches_paper_limit() {
        // 4096-deep RQ, window 32: supports 128 peers but not 256.
        let p = RecvPool::new(4096);
        assert!(p.can_provision(128, 32));
        assert!(!p.can_provision(256, 32));
    }

    #[test]
    fn retransmit_backs_off_and_gives_up() {
        let mut r = RetransmitState::armed(1000, 500, 2);
        assert_eq!(r.deadline, 1500);
        assert_eq!(r.on_timeout(1500), RetransmitDecision::Retry);
        assert_eq!(r.rto, 1000);
        assert_eq!(r.deadline, 2500);
        assert_eq!(r.on_timeout(2500), RetransmitDecision::Retry);
        assert_eq!(r.on_timeout(4500), RetransmitDecision::GiveUp);
    }
}
