//! NIC processing model: PU scheduling, state-cache charging, active-QP
//! tracking.
//!
//! Every verb passing through a NIC occupies one processing unit for a
//! *work* duration:
//!
//! ```text
//! work = stage_factor(op) * pu_service_ns * conn_penalty(active_qps)
//!      + payload_bytes * payload_ns_per_byte
//!      + misses * miss_cost()
//! ```
//!
//! where `misses` counts state-cache misses among the QP context, MPT and
//! MTT entries the op must consult. PUs are modeled as k identical
//! non-preemptive servers; an op admitted at time `t` starts at the
//! earliest PU-free instant and finishes `work` later.

use super::cache::{EntryKey, FxU64Hasher, NicCache};
use super::generations::NicGenParams;
use crate::mem::region::entry_sizes;
use crate::sim::Nanos;
use std::collections::HashMap;
use std::hash::BuildHasherDefault;

type FxSet = HashMap<u64, (), BuildHasherDefault<FxU64Hasher>>;

/// Cached send-queue state per connection (doorbell record + WQE
/// prefetch window), charged against the SRAM cache on slow-path posts.
const SQ_STATE_BYTES: u64 = 512;

/// Latency-path payload streaming cost (ns per byte at ~12.8 GB/s).
const PCIE_STREAM_NS_PER_BYTE: f64 = 0.08;

/// Which role the NIC plays for a verb (determines the stage cost).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NicSide {
    /// Requester transmit: WQE fetch + packet build.
    ReqTx,
    /// Requester receive of a response / generation of a CQE.
    ReqRxCqe,
    /// Responder servicing a one-sided READ (DMA fetch of payload).
    RespRead,
    /// Responder servicing a one-sided WRITE (DMA store of payload).
    RespWrite,
    /// Responder delivering a WRITE_WITH_IMM / SEND to a consumer:
    /// consumes an RQ descriptor and raises a CQE.
    RespRecvRc,
    /// Responder delivering a UD SEND: RQ descriptor + GRH handling +
    /// scatter (the paper's "managing receive queues in UD" overhead).
    RespRecvUd,
}

impl NicSide {
    /// Latency-visible stage factor multiplying `pu_service_ns`.
    fn stage_factor(self) -> f64 {
        match self {
            NicSide::ReqTx => 1.2,
            NicSide::ReqRxCqe => 0.5,
            NicSide::RespRead => 1.2,
            NicSide::RespWrite => 1.2,
            NicSide::RespRecvRc => 1.6,
            NicSide::RespRecvUd => 2.0,
        }
    }

    /// Capacity-only extra stage work (pipeline occupancy that PU
    /// concurrency hides from the op's own latency): RQ-descriptor
    /// replenish and scatter bookkeeping on the receive paths.
    fn hold_extra_factor(self) -> f64 {
        match self {
            NicSide::RespRecvRc => 0.9,
            NicSide::RespRecvUd => 1.6,
            _ => 0.0,
        }
    }

    /// Does this side drive the send pipeline (subject to the hot-QP
    /// slow-path switch)?
    fn uses_send_pipeline(self) -> bool {
        matches!(self, NicSide::ReqTx)
    }

    /// Does this side move payload through the DMA pipeline?
    fn moves_payload(self) -> bool {
        true
    }
}

/// A verb as seen by one NIC.
#[derive(Clone, Copy, Debug)]
pub struct NicOp {
    /// Role played by this NIC.
    pub side: NicSide,
    /// Global QP id the op runs on.
    pub qp: u64,
    /// Payload bytes.
    pub len: u32,
    /// Memory state consulted (responder roles): MPT entry id.
    pub mpt: Option<u64>,
    /// Memory state consulted (responder roles): first MTT entry id and
    /// the number of consecutive entries (pages) spanned. `None` for
    /// physical segments.
    pub mtt: Option<(u64, u32)>,
    /// Extra PU work in ns (e.g. UD receive-queue replenish charged to the
    /// NIC), on both the latency and capacity paths.
    pub extra_ns: f64,
    /// Extra PU *hold* in ns: capacity-only costs such as the software
    /// rate limiter's descriptor processing (hidden from op latency by PU
    /// concurrency, but it burns issue slots).
    pub extra_hold_ns: f64,
}

impl NicOp {
    /// Op with no memory-state touches (requester side).
    pub fn requester(side: NicSide, qp: u64, len: u32) -> Self {
        NicOp { side, qp, len, mpt: None, mtt: None, extra_ns: 0.0, extra_hold_ns: 0.0 }
    }
}

/// Cost breakdown for one op (for tests and perf accounting).
#[derive(Clone, Copy, Debug, Default)]
pub struct OpCost {
    /// Latency-visible work in ns (base stage + payload + miss stalls).
    pub work_ns: f64,
    /// PU-hold time in ns (`work_ns` inflated by the connection
    /// scheduling penalty) — throttles throughput, not latency.
    pub hold_ns: f64,
    /// State-cache misses charged.
    pub misses: u32,
    /// Connection penalty factor applied.
    pub conn_penalty: f64,
}

/// Tracks the number of *distinct QPs with recent work* using two epochs.
///
/// `active()` reports the max of the previous full epoch and the current
/// partial one — a deterministic approximation of "QPs busy right now".
struct ActiveQps {
    window: Nanos,
    epoch_start: Nanos,
    current: FxSet,
    prev_count: u32,
}

impl ActiveQps {
    fn new(window: Nanos) -> Self {
        ActiveQps { window, epoch_start: 0, current: FxSet::default(), prev_count: 0 }
    }

    fn touch(&mut self, now: Nanos, qp: u64) {
        if now >= self.epoch_start + self.window {
            self.prev_count = self.current.len() as u32;
            self.current.clear();
            self.epoch_start = now;
        }
        self.current.insert(qp, ());
    }

    fn active(&self) -> u32 {
        self.prev_count.max(self.current.len() as u32).max(1)
    }
}

/// One NIC instance (per simulated host).
pub struct Nic {
    /// Generation parameters.
    pub params: NicGenParams,
    /// SRAM state cache.
    pub cache: NicCache,
    pu_free: Vec<Nanos>,
    active: ActiveQps,
    /// Ops processed (all sides).
    pub ops_processed: u64,
    /// Accumulated PU work ns (for utilization reports).
    pub busy_ns: f64,
    /// If set, QP/MTT/MPT lookups bypass the cache entirely (LITE-style
    /// kernel-managed physical addressing: the NIC holds no per-page state).
    pub bypass_state_cache: bool,
    /// Send-pipeline fast-path slots (LRU over QP ids).
    hot_slots: NicCache,
}

impl Nic {
    /// New NIC of the given generation parameters.
    pub fn new(params: NicGenParams) -> Self {
        Self::with_host_threads(params, 1)
    }

    /// NIC serving a host with `threads` posting threads: the send
    /// pipeline's fast-path slots (doorbell pages + WQE prefetch state)
    /// are provisioned per thread, so sibling-connection traffic from many
    /// threads stays on the fast path while a single-context sweep over
    /// the same number of QPs (Fig. 1) does not.
    pub fn with_host_threads(params: NicGenParams, threads: u32) -> Self {
        let cache = NicCache::new(params.cache_bytes);
        let slots = (params.hot_qp_slots as u64 * threads.max(1) as u64).min(512);
        let hot_slots = NicCache::new(slots);
        let pus = params.pus as usize;
        Nic {
            params,
            cache,
            pu_free: vec![0; pus],
            active: ActiveQps::new(50 * crate::sim::MICRO),
            ops_processed: 0,
            busy_ns: 0.0,
            bypass_state_cache: false,
            hot_slots,
        }
    }

    /// Charge state-cache accesses for `op`; returns miss count.
    fn charge_cache(&mut self, op: &NicOp) -> u32 {
        if self.bypass_state_cache {
            return 0;
        }
        let mut misses = 0u32;
        if !self.cache.access(EntryKey::Qp(op.qp), entry_sizes::QP_CONTEXT) {
            misses += 1;
        }
        if let Some(mpt) = op.mpt {
            if !self.cache.access(EntryKey::Mpt(mpt), entry_sizes::MPT_ENTRY) {
                misses += 1;
            }
        }
        if let Some((base, n)) = op.mtt {
            for i in 0..n as u64 {
                if !self.cache.access(EntryKey::Mtt(base + i), entry_sizes::MTT_ENTRY) {
                    misses += 1;
                }
            }
        }
        misses
    }

    /// Compute the PU work for `op` at time `now` (also updates the caches
    /// and active-QP tracker).
    ///
    /// Posting on a QP outside the send pipeline's small fast-path LRU
    /// (`hot_qp_slots`) takes the slow path: `qp_switch_ns` of extra PU
    /// *hold* time. Capacity is lost, but the op's own latency is not —
    /// PU concurrency hides the switch when there is slack. This is what
    /// lets a lightly loaded cluster with thousands of established QPs
    /// keep its unloaded RTT and throughput (Fig. 7 stability at 64
    /// nodes) while the saturating Fig. 1 sweep degrades.
    pub fn op_cost(&mut self, now: Nanos, op: &NicOp) -> OpCost {
        self.active.touch(now, op.qp);
        let misses = self.charge_cache(op);
        let mut switch = 0.0;
        if op.side.uses_send_pipeline() && !self.hot_slots.access(EntryKey::Wqe(op.qp), 1) {
            // Slow path: replay the QP's doorbell/SQ state. If that state
            // has also fallen out of the SRAM cache (thousands of
            // connections), it must come over PCIe first.
            switch = self.params.qp_switch_ns;
            if !self.bypass_state_cache
                && !self.cache.access(EntryKey::Wqe(op.qp), SQ_STATE_BYTES)
            {
                switch += self.params.miss_cost();
            }
        }
        let stage = op.side.stage_factor() * self.params.pu_service_ns;
        let hold_stage = stage + op.side.hold_extra_factor() * self.params.pu_service_ns;
        // Payload: the *latency* cost is the raw PCIe/DMA streaming time
        // (~12.8 GB/s, largely pipelined with the wire); the *capacity*
        // cost is the full gather/scatter pipeline occupancy.
        let payload_latency = op.len as f64 * PCIE_STREAM_NS_PER_BYTE;
        let payload_hold = if op.side.moves_payload() {
            op.len as f64 * self.params.payload_ns_per_byte
        } else {
            0.0
        };
        let shared = misses as f64 * self.params.miss_cost() + op.extra_ns;
        OpCost {
            work_ns: stage + shared + payload_latency,
            hold_ns: hold_stage + shared + payload_hold + switch + op.extra_hold_ns,
            misses,
            conn_penalty: if switch > 0.0 { 2.0 } else { 1.0 },
        }
    }

    /// Admit an op at `now`: occupies the earliest-free PU for `hold_ns`,
    /// returns the op's completion time (`start + work_ns`).
    pub fn admit(&mut self, now: Nanos, cost: &OpCost) -> Nanos {
        // Earliest-free PU (k small: linear scan).
        let mut best = 0usize;
        for i in 1..self.pu_free.len() {
            if self.pu_free[i] < self.pu_free[best] {
                best = i;
            }
        }
        let start = self.pu_free[best].max(now);
        self.pu_free[best] = start + cost.hold_ns.round() as Nanos;
        self.ops_processed += 1;
        self.busy_ns += cost.hold_ns;
        start + cost.work_ns.round() as Nanos
    }

    /// Convenience: cost + admit in one call.
    pub fn process(&mut self, now: Nanos, op: &NicOp) -> (Nanos, OpCost) {
        let cost = self.op_cost(now, op);
        let finish = self.admit(now, &cost);
        (finish, cost)
    }

    /// Current active-QP estimate (for tests/reports).
    pub fn active_qps(&self) -> u32 {
        self.active.active()
    }

    /// Pre-warm the state cache (steady-state measurements: the real
    /// benchmarks run for seconds, so translation/context state is warm).
    pub fn prewarm(
        &mut self,
        qps: impl Iterator<Item = u64>,
        mpts: impl Iterator<Item = u64>,
        mtts: impl Iterator<Item = u64>,
    ) {
        for q in qps {
            self.cache.access(EntryKey::Qp(q), entry_sizes::QP_CONTEXT);
        }
        for m in mpts {
            self.cache.access(EntryKey::Mpt(m), entry_sizes::MPT_ENTRY);
        }
        for t in mtts {
            self.cache.access(EntryKey::Mtt(t), entry_sizes::MTT_ENTRY);
        }
        self.cache.reset_counters();
    }

    /// PU utilization over `elapsed` ns of simulated time.
    pub fn utilization(&self, elapsed: Nanos) -> f64 {
        if elapsed == 0 {
            return 0.0;
        }
        self.busy_ns / (elapsed as f64 * self.params.pus as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nic::generations::NicGen;

    fn cx5() -> Nic {
        Nic::new(NicGen::Cx5.params())
    }

    #[test]
    fn cost_includes_payload() {
        let mut nic = cx5();
        nic.op_cost(0, &NicOp::requester(NicSide::ReqTx, 1, 64)); // warm QP
        let small = nic.op_cost(0, &NicOp::requester(NicSide::ReqTx, 1, 64));
        let big = nic.op_cost(1, &NicOp::requester(NicSide::ReqTx, 1, 4096));
        // Capacity (hold) pays the full gather/scatter pipeline...
        assert!(big.hold_ns > small.hold_ns + 1000.0);
        // ...while latency only pays the raw streaming time.
        assert!(big.work_ns > small.work_ns + 200.0);
        assert!(big.work_ns < small.work_ns + 600.0);
    }

    #[test]
    fn cqe_payload_is_mostly_capacity_cost() {
        let mut nic = cx5();
        nic.op_cost(0, &NicOp::requester(NicSide::ReqRxCqe, 1, 64)); // warm QP
        let a = nic.op_cost(1, &NicOp::requester(NicSide::ReqRxCqe, 1, 64));
        let b = nic.op_cost(2, &NicOp::requester(NicSide::ReqRxCqe, 1, 65536));
        let d_work = b.work_ns - a.work_ns;
        let d_hold = b.hold_ns - a.hold_ns;
        assert!(d_hold > 5.0 * d_work, "hold {d_hold} vs work {d_work}");
    }

    #[test]
    fn misses_increase_cost() {
        let mut nic = cx5();
        let op = NicOp {
            side: NicSide::RespRead,
            qp: 7,
            len: 64,
            mpt: Some(3),
            mtt: Some((100, 1)),
            extra_ns: 0.0,
            extra_hold_ns: 0.0,
        };
        let cold = nic.op_cost(0, &op);
        let warm = nic.op_cost(1, &op);
        assert_eq!(cold.misses, 3); // QP + MPT + MTT all cold
        assert_eq!(warm.misses, 0);
        assert!(cold.work_ns > warm.work_ns);
    }

    #[test]
    fn physseg_ops_skip_mtt() {
        let mut nic = cx5();
        let op = NicOp { side: NicSide::RespRead, qp: 1, len: 64, mpt: Some(0), mtt: None, extra_ns: 0.0, extra_hold_ns: 0.0 };
        let cold = nic.op_cost(0, &op);
        assert_eq!(cold.misses, 2); // QP + MPT only
    }

    #[test]
    fn pus_run_in_parallel() {
        let mut nic = cx5();
        let pus = nic.params.pus as u64;
        let cost = OpCost { work_ns: 100.0, hold_ns: 100.0, misses: 0, conn_penalty: 1.0 };
        // Admit `pus` ops at t=0: all should finish at work, not serially.
        for _ in 0..pus {
            let f = nic.admit(0, &cost);
            assert_eq!(f, 100);
        }
        // One more queues behind the earliest.
        let f = nic.admit(0, &cost);
        assert_eq!(f, 200);
    }

    #[test]
    fn penalty_throttles_capacity_not_latency() {
        let mut nic = cx5();
        // Inflated hold: completion still at start + work, but the PU is
        // held longer, delaying the next admission.
        let cost = OpCost { work_ns: 100.0, hold_ns: 300.0, misses: 0, conn_penalty: 3.0 };
        for _ in 0..nic.params.pus {
            let f = nic.admit(0, &cost);
            assert_eq!(f, 100, "latency must not include the penalty");
        }
        let f = nic.admit(0, &cost);
        assert_eq!(f, 400, "next op queues behind the inflated hold");
    }

    #[test]
    fn hot_qp_slots_gate_the_switch_cost() {
        let mut nic = cx5();
        let slots = nic.params.hot_qp_slots as u64;
        // Round-robin within the slot budget: everything stays hot after
        // the first pass.
        for _pass in 0..2 {
            for qp in 0..slots {
                nic.op_cost(qp, &NicOp::requester(NicSide::ReqTx, qp, 64));
            }
        }
        let hot = nic.op_cost(100, &NicOp::requester(NicSide::ReqTx, 0, 64));
        assert_eq!(hot.conn_penalty, 1.0, "hot QP pays no switch");
        // Spray 4x the slot count: most posts now take the slow path.
        let mut slow = 0;
        for qp in 0..4 * slots {
            let c = nic.op_cost(200, &NicOp::requester(NicSide::ReqTx, qp, 64));
            if c.conn_penalty > 1.0 {
                slow += 1;
            }
        }
        assert!(slow as u64 > 2 * slots, "slow-path posts: {slow}");
        // Receive-side stages never pay the send-pipeline switch.
        let rx = nic.op_cost(300, &NicOp::requester(NicSide::RespRead, 999_999, 64));
        assert_eq!(rx.conn_penalty, 1.0);
    }

    #[test]
    fn active_qps_decay_after_idle_epochs() {
        let mut nic = cx5();
        for qp in 0..256u64 {
            nic.op_cost(qp, &NicOp::requester(NicSide::ReqTx, qp, 64));
        }
        // Two full windows later only one QP is busy.
        let later = 2 * 50 * crate::sim::MICRO + 1000;
        nic.op_cost(later, &NicOp::requester(NicSide::ReqTx, 1, 64));
        let much_later = 2 * later;
        nic.op_cost(much_later, &NicOp::requester(NicSide::ReqTx, 1, 64));
        assert!(nic.active_qps() < 8, "active: {}", nic.active_qps());
    }

    #[test]
    fn bypass_state_cache_has_no_misses() {
        let mut nic = cx5();
        nic.bypass_state_cache = true;
        let op = NicOp {
            side: NicSide::RespRead,
            qp: 9,
            len: 64,
            mpt: Some(1),
            mtt: Some((5, 4)),
            extra_ns: 0.0,
            extra_hold_ns: 0.0,
        };
        assert_eq!(nic.op_cost(0, &op).misses, 0);
    }

    #[test]
    fn utilization_tracks_busy_time() {
        let mut nic = cx5();
        let cost = OpCost { work_ns: 1000.0, hold_ns: 1000.0, misses: 0, conn_penalty: 1.0 };
        nic.admit(0, &cost);
        let u = nic.utilization(1000);
        assert!((u - 1.0 / nic.params.pus as f64).abs() < 1e-6);
    }

    #[test]
    fn ud_recv_costs_more_than_rc_recv() {
        let mut nic = cx5();
        // warm the QP
        nic.op_cost(0, &NicOp::requester(NicSide::RespRecvRc, 1, 128));
        let rc = nic.op_cost(1, &NicOp::requester(NicSide::RespRecvRc, 1, 128));
        let ud = nic.op_cost(2, &NicOp::requester(NicSide::RespRecvUd, 1, 128));
        assert!(ud.work_ns > rc.work_ns);
    }
}
