//! RDMA NIC model.
//!
//! The paper's whole scalability argument is about what fits in the NIC's
//! SRAM cache (QP contexts, MTTs, MPTs, WQEs — its Table 1) and how well
//! the NIC's processing units (PUs) hide PCIe fetches on a miss. This
//! module models exactly those quantities:
//!
//! * [`cache::NicCache`] — a byte-budgeted LRU over typed state entries.
//! * [`generations`] — CX3 / CX4 / CX5 parameter sets calibrated to the
//!   paper's Figure 1 observations (83% / 42% / 32% throughput drop from 8
//!   to 64 connections; ~10 req/µs CX5 floor at zero hit rate; ~40 M
//!   reads/s CX5 peak).
//! * [`model::Nic`] — PU scheduling: each verb occupies a PU for a service
//!   time inflated by cache misses and (per-generation) how much of the
//!   PCIe miss latency concurrent PUs can hide.

pub mod cache;
pub mod generations;
pub mod model;

pub use cache::{EntryKey, NicCache};
pub use generations::{NicGen, NicGenParams};
pub use model::{Nic, NicOp, NicSide, OpCost};
