//! NIC SRAM cache: a byte-budgeted LRU over typed transport-state entries.
//!
//! Keys identify the cached object (QP context, MTT entry, MPT entry); each
//! key class has a fixed entry size (see [`crate::mem::region::entry_sizes`]).
//! The implementation is a hash map into a slab of intrusively linked nodes
//! — O(1) touch/insert/evict, deterministic, no allocation after warmup.

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

/// Identifies one cacheable piece of NIC state.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum EntryKey {
    /// QP context (metadata + congestion control), keyed globally.
    Qp(u64),
    /// Memory translation table entry (one page), keyed globally per host.
    Mtt(u64),
    /// Memory protection table entry (one region).
    Mpt(u64),
    /// Work queue entry state for an outstanding op.
    Wqe(u64),
}

impl EntryKey {
    /// Pack into a u64 (class tag in the top 2 bits) — the map key.
    /// Ids comfortably fit 62 bits (page/QP/region counts).
    #[inline]
    fn pack(self) -> u64 {
        match self {
            EntryKey::Qp(id) => id,
            EntryKey::Mtt(id) => (1 << 62) | id,
            EntryKey::Mpt(id) => (2 << 62) | id,
            EntryKey::Wqe(id) => (3 << 62) | id,
        }
    }
}

/// Fx-style multiply hasher for the packed keys: the state cache is the
/// hottest structure in the simulator (one lookup per NIC state touch),
/// and the default SipHash costs ~10x more (see EXPERIMENTS.md §Perf).
#[derive(Default)]
pub struct FxU64Hasher(u64);

impl Hasher for FxU64Hasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.0
    }
    #[inline]
    fn write(&mut self, _bytes: &[u8]) {
        unreachable!("only u64 keys are hashed");
    }
    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.0 = n.wrapping_mul(0x9E37_79B9_7F4A_7C15).rotate_left(26);
    }
}

type FastMap = HashMap<u64, u32, BuildHasherDefault<FxU64Hasher>>;

const NIL: u32 = u32::MAX;

struct Node {
    key: EntryKey,
    size: u32,
    prev: u32,
    next: u32,
}

/// Byte-budgeted LRU cache.
pub struct NicCache {
    capacity: u64,
    used: u64,
    map: FastMap,
    nodes: Vec<Node>,
    free: Vec<u32>,
    head: u32, // most recent
    tail: u32, // least recent
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl NicCache {
    /// Cache with `capacity` bytes of SRAM.
    pub fn new(capacity: u64) -> Self {
        NicCache {
            capacity,
            used: 0,
            map: FastMap::default(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    /// Access `key` of `size` bytes; returns `true` on a hit. On a miss the
    /// entry is installed, evicting LRU entries to fit.
    pub fn access(&mut self, key: EntryKey, size: u64) -> bool {
        let packed = key.pack();
        if let Some(&idx) = self.map.get(&packed) {
            self.unlink(idx);
            self.push_front(idx);
            self.hits += 1;
            return true;
        }
        self.misses += 1;
        if size > self.capacity {
            // Uncacheable (degenerate config); count as a pure miss.
            return false;
        }
        while self.used + size > self.capacity {
            self.evict_lru();
        }
        let idx = self.alloc_node(key, size as u32);
        self.push_front(idx);
        self.map.insert(packed, idx);
        self.used += size;
        false
    }

    /// Remove an entry (e.g., QP destroyed, region deregistered).
    pub fn invalidate(&mut self, key: EntryKey) {
        if let Some(idx) = self.map.remove(&key.pack()) {
            self.unlink(idx);
            self.used -= self.nodes[idx as usize].size as u64;
            self.free.push(idx);
        }
    }

    fn evict_lru(&mut self) {
        let idx = self.tail;
        assert_ne!(idx, NIL, "evicting from empty cache");
        self.unlink(idx);
        let node = &self.nodes[idx as usize];
        self.map.remove(&node.key.pack());
        self.used -= node.size as u64;
        self.free.push(idx);
        self.evictions += 1;
    }

    fn alloc_node(&mut self, key: EntryKey, size: u32) -> u32 {
        if let Some(idx) = self.free.pop() {
            let n = &mut self.nodes[idx as usize];
            n.key = key;
            n.size = size;
            n.prev = NIL;
            n.next = NIL;
            idx
        } else {
            self.nodes.push(Node { key, size, prev: NIL, next: NIL });
            (self.nodes.len() - 1) as u32
        }
    }

    fn unlink(&mut self, idx: u32) {
        let (prev, next) = {
            let n = &self.nodes[idx as usize];
            (n.prev, n.next)
        };
        if prev != NIL {
            self.nodes[prev as usize].next = next;
        } else if self.head == idx {
            self.head = next;
        }
        if next != NIL {
            self.nodes[next as usize].prev = prev;
        } else if self.tail == idx {
            self.tail = prev;
        }
        let n = &mut self.nodes[idx as usize];
        n.prev = NIL;
        n.next = NIL;
    }

    fn push_front(&mut self, idx: u32) {
        self.nodes[idx as usize].next = self.head;
        self.nodes[idx as usize].prev = NIL;
        if self.head != NIL {
            self.nodes[self.head as usize].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    /// Bytes currently cached.
    pub fn used(&self) -> u64 {
        self.used
    }

    /// Cache capacity in bytes.
    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Number of resident entries.
    pub fn entries(&self) -> usize {
        self.map.len()
    }

    /// Hit count since creation/reset.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Miss count since creation/reset.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Capacity evictions since creation/reset — the direct signal that
    /// the transport-state working set has outgrown the SRAM.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Hit rate in `[0, 1]` (1.0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            1.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Reset hit/miss counters (not contents) — used at measurement-window
    /// boundaries.
    pub fn reset_counters(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.evictions = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_after_insert() {
        let mut c = NicCache::new(1024);
        assert!(!c.access(EntryKey::Qp(1), 375));
        assert!(c.access(EntryKey::Qp(1), 375));
        assert_eq!(c.entries(), 1);
        assert_eq!(c.used(), 375);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = NicCache::new(300);
        c.access(EntryKey::Mtt(1), 100);
        c.access(EntryKey::Mtt(2), 100);
        c.access(EntryKey::Mtt(3), 100);
        // Touch 1 so 2 becomes LRU.
        assert!(c.access(EntryKey::Mtt(1), 100));
        c.access(EntryKey::Mtt(4), 100); // evicts 2
        assert!(c.access(EntryKey::Mtt(1), 100));
        assert!(c.access(EntryKey::Mtt(3), 100));
        assert!(!c.access(EntryKey::Mtt(2), 100), "2 was evicted");
    }

    #[test]
    fn occupancy_never_exceeds_capacity() {
        let mut c = NicCache::new(1000);
        for i in 0..10_000u64 {
            c.access(EntryKey::Mtt(i % 57), 64);
            assert!(c.used() <= c.capacity());
        }
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        let mut c = NicCache::new(64 * 10); // holds 10 entries
        // Cyclic scan over 20 entries: classic LRU worst case — ~0% hits.
        for _ in 0..10 {
            for i in 0..20u64 {
                c.access(EntryKey::Mtt(i), 64);
            }
        }
        assert!(c.hit_rate() < 0.05, "hit rate {}", c.hit_rate());
    }

    #[test]
    fn working_set_fitting_cache_hits() {
        let mut c = NicCache::new(64 * 32);
        for _ in 0..100 {
            for i in 0..20u64 {
                c.access(EntryKey::Mtt(i), 64);
            }
        }
        assert!(c.hit_rate() > 0.98, "hit rate {}", c.hit_rate());
    }

    #[test]
    fn invalidate_frees_space() {
        let mut c = NicCache::new(200);
        c.access(EntryKey::Qp(1), 150);
        c.invalidate(EntryKey::Qp(1));
        assert_eq!(c.used(), 0);
        assert!(!c.access(EntryKey::Qp(1), 150));
    }

    #[test]
    fn distinct_classes_do_not_alias() {
        let mut c = NicCache::new(1 << 20);
        c.access(EntryKey::Qp(7), 375);
        assert!(!c.access(EntryKey::Mtt(7), 8));
        assert!(!c.access(EntryKey::Mpt(7), 64));
        assert!(!c.access(EntryKey::Wqe(7), 64));
        assert_eq!(c.entries(), 4);
    }

    #[test]
    fn evictions_counted() {
        let mut c = NicCache::new(64 * 4);
        for i in 0..10u64 {
            c.access(EntryKey::Qp(i), 64);
        }
        assert_eq!(c.evictions(), 6);
        c.reset_counters();
        assert_eq!(c.evictions(), 0);
    }

    #[test]
    fn oversized_entry_not_cached() {
        let mut c = NicCache::new(100);
        assert!(!c.access(EntryKey::Mpt(1), 500));
        assert!(!c.access(EntryKey::Mpt(1), 500));
        assert_eq!(c.used(), 0);
    }
}
