//! Per-generation NIC parameter sets (the paper's CX3 / CX4 / CX5 study).
//!
//! Absolute constants are *calibration knobs*, not datasheet values: they
//! are chosen so the model reproduces the paper's published observables
//! (DESIGN.md §8):
//!
//! * CX5 peaks near 40 M one-sided reads/s and floors near 10 req/µs once
//!   every lookup misses the NIC cache (Fig. 1);
//! * going from 8 to 64 *concurrently active* connections costs 83% / 42% /
//!   32% of throughput on CX3 / CX4 / CX5 (Fig. 1);
//! * CX3 has a small SRAM cache and few processing units; CX4/CX5 have
//!   ~2 MB caches, more PUs, and prefetching that hides part of the PCIe
//!   fetch on a miss (§3.3 "larger caches, better cache management").
//!
//! The connection penalty models QP scheduling/arbitration cost across the
//! *active* QP working set (QPs with recent work), not merely established
//! connections — this is what lets a 64-node cluster with 2·m·t established
//! QPs run at full speed (Fig. 7) while the Fig. 1 sweep, which keeps every
//! connection busy, degrades.



/// NIC hardware generation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NicGen {
    /// ConnectX-3 Pro (40 Gbps RoCE in the paper's testbed).
    Cx3,
    /// ConnectX-4 (100 Gbps, IB EDR cluster + RoCE pair).
    Cx4,
    /// ConnectX-5 (100 Gbps RoCE pair).
    Cx5,
}

impl NicGen {
    /// Parameter set for this generation.
    pub fn params(self) -> NicGenParams {
        match self {
            NicGen::Cx3 => NicGenParams {
                name: "CX3",
                cache_bytes: 192 << 10,
                pus: 2,
                pu_service_ns: 110.0,
                pcie_miss_ns: 800.0,
                miss_hide: 0.0,
                hot_qp_slots: 8,
                qp_switch_ns: 1100.0,
                payload_ns_per_byte: 0.60,
                link_gbps: 40.0,
            },
            NicGen::Cx4 => NicGenParams {
                name: "CX4",
                cache_bytes: 2 << 20,
                pus: 6,
                pu_service_ns: 110.0,
                pcie_miss_ns: 750.0,
                miss_hide: 0.45,
                hot_qp_slots: 16,
                qp_switch_ns: 190.0,
                payload_ns_per_byte: 0.75,
                link_gbps: 100.0,
            },
            NicGen::Cx5 => NicGenParams {
                name: "CX5",
                cache_bytes: 2 << 20,
                pus: 8,
                pu_service_ns: 100.0,
                pcie_miss_ns: 750.0,
                miss_hide: 0.45,
                hot_qp_slots: 32,
                qp_switch_ns: 170.0,
                payload_ns_per_byte: 0.50,
                link_gbps: 100.0,
            },
        }
    }
}

/// Calibrated NIC model parameters.
#[derive(Clone, Debug)]
pub struct NicGenParams {
    /// Generation name for reports.
    pub name: &'static str,
    /// SRAM cache budget for QP/MTT/MPT state.
    pub cache_bytes: u64,
    /// Processing units able to work on verbs concurrently.
    pub pus: u32,
    /// Base PU work per pipeline stage (ns).
    pub pu_service_ns: f64,
    /// Full PCIe round trip to fetch state on a cache miss (ns).
    pub pcie_miss_ns: f64,
    /// Fraction of the miss penalty hidden by prefetch/PU concurrency.
    pub miss_hide: f64,
    /// Send-queue fast-path slots: QPs whose doorbell/WQE state the NIC
    /// keeps in registers. Posting on a QP outside this LRU set takes the
    /// slow path (`qp_switch_ns`). The root of Fig. 1's decline.
    pub hot_qp_slots: u32,
    /// Slow-path cost of switching the send pipeline to a cold QP. Charged
    /// to PU *hold* (issue capacity), not op latency — with PU slack it is
    /// hidden, which is why a lightly loaded 64-node cluster (Fig. 7) does
    /// not see it while the saturating Fig. 1 sweep does.
    pub qp_switch_ns: f64,
    /// PU work per payload byte moved (DMA gather/scatter pipeline).
    pub payload_ns_per_byte: f64,
    /// Port line rate.
    pub link_gbps: f64,
}

impl NicGenParams {
    /// Effective extra PU-work for one state-cache miss.
    pub fn miss_cost(&self) -> f64 {
        self.pcie_miss_ns * (1.0 - self.miss_hide)
    }

    /// Link serialization time for a payload of `bytes` (ns).
    pub fn wire_ns(&self, bytes: u64) -> f64 {
        bytes as f64 * 8.0 / self.link_gbps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cx5_peak_near_40m_reads() {
        // Requester does a TX (1.2) + CQE (0.5) stage per read: the PU
        // capacity bound should land near the paper's ~40M reads/s.
        let p = NicGen::Cx5.params();
        let peak = p.pus as f64 / (1.7 * p.pu_service_ns) * 1e3; // Mops
        assert!((35.0..55.0).contains(&peak), "peak {peak:.1}");
    }

    #[test]
    fn newer_generations_strictly_better() {
        let (c3, c4, c5) = (NicGen::Cx3.params(), NicGen::Cx4.params(), NicGen::Cx5.params());
        assert!(c3.cache_bytes < c4.cache_bytes);
        assert!(c3.pus < c4.pus && c4.pus < c5.pus);
        assert!(c3.miss_hide < c4.miss_hide && c4.miss_hide <= c5.miss_hide);
        assert!(c3.hot_qp_slots < c4.hot_qp_slots && c4.hot_qp_slots < c5.hot_qp_slots);
        assert!(c3.qp_switch_ns > c4.qp_switch_ns && c4.qp_switch_ns > c5.qp_switch_ns);
    }

    #[test]
    fn miss_cost_positive_and_hidden() {
        let p = NicGen::Cx5.params();
        assert!(p.miss_cost() > 0.0);
        assert!(p.miss_cost() < p.pcie_miss_ns);
        let c3 = NicGen::Cx3.params();
        assert_eq!(c3.miss_cost(), c3.pcie_miss_ns); // no hiding on CX3
    }

    #[test]
    fn wire_time_scales_with_size() {
        let p = NicGen::Cx4.params();
        assert!((p.wire_ns(128) - 10.24).abs() < 1e-9);
        assert!((p.wire_ns(1024) - 81.92).abs() < 1e-9);
    }
}
