//! YCSB-style scan workload (Workload E of Cooper et al., SoCC '10).
//!
//! Workload E is the scan-shaped member of the YCSB core suite: 95%
//! short range scans / 5% inserts of fresh records. It is the natural
//! stress for the B-link fence-chain scan path (PR 10) — every scan
//! walks one-sided next-leaf hops, and the insert trickle keeps leaves
//! splitting underneath the walkers, exercising the fence-validated
//! repair path rather than a frozen tree.
//!
//! Scan start keys are sampled uniformly (or Zipfian-skewed for
//! contention studies) over the loaded keyspace; scan lengths are
//! uniform in `1..=max_scan_len` per the YCSB default. Insert keys grow
//! monotonically past the loaded keyspace, strided by client id so
//! concurrent clients never collide.

use crate::sim::{Pcg64, Zipf};

/// One YCSB-E operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum YcsbOp {
    /// Range scan of `len` keys starting at `low` (inclusive); the
    /// matching `lookup_range` bound is [`YcsbOp::scan_bounds`].
    Scan { low: u64, len: u64 },
    /// Insert a fresh record (key beyond the loaded keyspace).
    Insert { key: u64 },
}

impl YcsbOp {
    /// Inclusive `(low, high)` bounds a `Scan` op covers.
    pub fn scan_bounds(low: u64, len: u64) -> (u64, u64) {
        (low, low + len.max(1) - 1)
    }
}

/// Workload-E sampler state (one per client thread).
#[derive(Clone, Debug)]
pub struct YcsbEWorkload {
    /// Keys loaded before the run (scan starts sample `1..=total_keys`).
    pub total_keys: u64,
    /// Scan lengths are uniform in `1..=max_scan_len` (YCSB default).
    pub max_scan_len: u64,
    /// Fraction of operations that are inserts (YCSB-E: 0.05).
    pub insert_fraction: f64,
    /// Next fresh insert key for this client.
    next_insert: u64,
    /// Insert-key stride (number of concurrent clients).
    stride: u64,
    /// Optional Zipfian skew on scan start keys (None = uniform).
    zipf: Option<Zipf>,
}

impl YcsbEWorkload {
    /// Standard Workload E: uniform scan starts, 95/5 scan/insert mix.
    pub fn uniform(total_keys: u64, max_scan_len: u64) -> Self {
        YcsbEWorkload {
            total_keys,
            max_scan_len: max_scan_len.max(1),
            insert_fraction: 0.05,
            next_insert: total_keys + 1,
            stride: 1,
            zipf: None,
        }
    }

    /// Zipfian-skewed scan starts (hot-range contention variant).
    pub fn zipfian(total_keys: u64, max_scan_len: u64, theta: f64) -> Self {
        YcsbEWorkload {
            zipf: Some(Zipf::new(total_keys, theta)),
            ..Self::uniform(total_keys, max_scan_len)
        }
    }

    /// Stride this client's insert keys so `clients` concurrent samplers
    /// produce disjoint fresh keys (client ids `0..clients`).
    pub fn for_client(mut self, client: u64, clients: u64) -> Self {
        let clients = clients.max(1);
        self.next_insert = self.total_keys + 1 + client;
        self.stride = clients;
        self
    }

    /// Sample the next operation.
    pub fn next_op(&mut self, rng: &mut Pcg64) -> YcsbOp {
        if rng.gen_bool(self.insert_fraction) {
            let key = self.next_insert;
            self.next_insert += self.stride;
            return YcsbOp::Insert { key };
        }
        let low = match &self.zipf {
            Some(z) => z.sample(rng) + 1,
            None => rng.gen_range(self.total_keys) + 1,
        };
        YcsbOp::Scan { low, len: rng.gen_range(self.max_scan_len) + 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_is_scan_heavy_and_in_range() {
        let mut w = YcsbEWorkload::uniform(10_000, 100);
        let mut rng = Pcg64::seeded(1);
        let (mut scans, mut inserts) = (0u64, 0u64);
        for _ in 0..20_000 {
            match w.next_op(&mut rng) {
                YcsbOp::Scan { low, len } => {
                    assert!((1..=10_000).contains(&low), "scan low {low}");
                    assert!((1..=100).contains(&len), "scan len {len}");
                    scans += 1;
                }
                YcsbOp::Insert { key } => {
                    assert!(key > 10_000, "insert key {key} inside loaded keyspace");
                    inserts += 1;
                }
            }
        }
        // 5% insert fraction: expect roughly 1000 of 20k, generously bounded.
        assert!(scans > 17_000, "scans {scans}");
        assert!((400..2_000).contains(&inserts), "inserts {inserts}");
    }

    #[test]
    fn client_strides_never_collide() {
        let mut seen = std::collections::HashSet::new();
        for client in 0..4u64 {
            let mut w = YcsbEWorkload::uniform(1_000, 10).for_client(client, 4);
            w.insert_fraction = 1.0; // force inserts
            let mut rng = Pcg64::seeded(10 + client);
            for _ in 0..500 {
                let YcsbOp::Insert { key } = w.next_op(&mut rng) else { unreachable!() };
                assert!(seen.insert(key), "duplicate insert key {key}");
            }
        }
    }

    #[test]
    fn scan_bounds_are_inclusive() {
        assert_eq!(YcsbOp::scan_bounds(7, 10), (7, 16));
        assert_eq!(YcsbOp::scan_bounds(7, 1), (7, 7));
        assert_eq!(YcsbOp::scan_bounds(7, 0), (7, 7));
    }

    #[test]
    fn zipf_skews_scan_starts() {
        let mut w = YcsbEWorkload::zipfian(100_000, 10, 0.99);
        w.insert_fraction = 0.0;
        let mut rng = Pcg64::seeded(4);
        let mut head = 0;
        for _ in 0..20_000 {
            if let YcsbOp::Scan { low, .. } = w.next_op(&mut rng) {
                if low <= 1_000 {
                    head += 1;
                }
            }
        }
        assert!(head > 5_000, "zipf head {head}");
    }
}
