//! TATP — Telecom Application Transaction Processing (paper §6.1, Fig. 6).
//!
//! The standard benchmark simulating a Home Location Register: four tables
//! keyed by subscriber id, seven transaction types with the canonical mix
//! (80% reads, 16% writes, 4% inserts/deletes — exactly the fractions the
//! paper quotes). The four tables map to four Storm objects
//! ([`SUBSCRIBER`]..[`CALL_FORWARDING`]); every transaction becomes a
//! read set + write set executed by the Storm transactional protocol.
//!
//! Since the storage catalog ([`crate::ds::catalog`]), TATP runs
//! **natively on four tables** everywhere: the simulator always did, the
//! reference driver hosts a four-object [`crate::dataplane::local::LocalCluster`],
//! and the live loopback cluster hosts the four-object catalog built by
//! [`live_catalog`], with [`TatpTx::sets`] producing the native
//! `(read set, write set)` pair. [`flat_key`] / [`TatpTx::flatten`] /
//! [`TatpPopulation::flat_rows`] survive only as **legacy shims** for the
//! pre-catalog single-table projection (the old bench compat mode and the
//! flattened-vs-native equivalence tests).
//!
//! Key encoding (single-u64 keys for the MICA tables):
//! * SUBSCRIBER:        `s_id`
//! * ACCESS_INFO:       `s_id * 4 + (ai_type - 1)`
//! * SPECIAL_FACILITY:  `s_id * 4 + (sf_type - 1)`
//! * CALL_FORWARDING:   `(s_id * 4 + (sf_type - 1)) * 3 + start_time / 8`

use crate::dataplane::tx::TxItem;
use crate::ds::api::ObjectId;
use crate::ds::btree::BTreeConfig;
use crate::ds::catalog::{buckets_for, CatalogConfig, ObjectConfig};
use crate::ds::mica::MicaConfig;
use crate::sim::Pcg64;

/// Object ids of the four TATP tables.
pub const SUBSCRIBER: ObjectId = ObjectId(0);
/// ACCESS_INFO table.
pub const ACCESS_INFO: ObjectId = ObjectId(1);
/// SPECIAL_FACILITY table.
pub const SPECIAL_FACILITY: ObjectId = ObjectId(2);
/// CALL_FORWARDING table.
pub const CALL_FORWARDING: ObjectId = ObjectId(3);

/// Encode an ACCESS_INFO / SPECIAL_FACILITY key.
pub fn sf_key(s_id: u64, typ: u64) -> u64 {
    debug_assert!((1..=4).contains(&typ));
    s_id * 4 + (typ - 1)
}

/// Encode a CALL_FORWARDING key.
pub fn cf_key(s_id: u64, sf_type: u64, start_time: u64) -> u64 {
    debug_assert!(start_time % 8 == 0 && start_time <= 16);
    sf_key(s_id, sf_type) * 3 + start_time / 8
}

/// **Legacy shim** (pre-catalog): flatten a `(table, key)` pair onto a
/// single-object keyspace, the projection the live cluster needed when it
/// served exactly one MICA table per node. The object id rides in the low
/// two bits, keeping the four tables disjoint; every TATP key is ≥ 1, so
/// flattened keys are nonzero (0 is the empty-slot marker). New code
/// should run natively on the four catalog objects ([`live_catalog`],
/// [`TatpTx::sets`]); this stays for the bench's compat mode and the
/// flattened-vs-native equivalence tests.
pub fn flat_key(obj: ObjectId, key: u64) -> u64 {
    debug_assert!(obj.0 < 4 && key >= 1);
    key * 4 + obj.0 as u64
}

/// Approximate rows per subscriber in each table (SUB / AI / SF / CF) —
/// the population averages used to size the four catalog tables (also
/// the ratios the simulator uses).
pub const ROWS_PER_SUBSCRIBER: [f64; 4] = [1.0, 2.5, 2.5, 3.75];

/// The four-object live catalog for a TATP database of `subscribers`,
/// each table sized for its expected row count at ~50% inline occupancy
/// (width-2 buckets), values `value_len` bytes.
pub fn live_catalog(subscribers: u64, value_len: u32) -> CatalogConfig {
    CatalogConfig::new(
        ROWS_PER_SUBSCRIBER
            .iter()
            .map(|rows| MicaConfig {
                buckets: buckets_for((subscribers as f64 * rows).ceil() as u64, 2),
                width: 2,
                value_len,
                store_values: true,
            })
            .collect(),
    )
}

/// The heterogeneous TATP catalog (PR 5): SUBSCRIBER / ACCESS_INFO /
/// SPECIAL_FACILITY stay MICA tables, but CALL_FORWARDING — the one
/// table the mix inserts into and deletes from — is backed by a B-link
/// tree. Its transactions exercise leaf-granularity OCC live:
/// `GetNewDestination` validates a leaf header alongside a MICA item
/// header in one doorbell volley, and `Insert`/`DeleteCallForwarding`
/// write through the tree (inserts split leaves under load, which is
/// exactly the `ValidationMoved` race the test battery pins down). The
/// leaf budget leaves generous split headroom.
pub fn live_catalog_btree_cf(subscribers: u64, value_len: u32) -> CatalogConfig {
    let mut objects: Vec<ObjectConfig> = ROWS_PER_SUBSCRIBER[..3]
        .iter()
        .map(|rows| {
            ObjectConfig::Mica(MicaConfig {
                buckets: buckets_for((subscribers as f64 * rows).ceil() as u64, 2),
                width: 2,
                value_len,
                store_values: true,
            })
        })
        .collect();
    let cf_rows = (subscribers as f64 * ROWS_PER_SUBSCRIBER[3]).ceil() as u64;
    objects.push(ObjectConfig::BTree(BTreeConfig { max_leaves: (cf_rows / 2).max(64) }));
    CatalogConfig::heterogeneous(objects)
}

/// The seven TATP transaction types.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TatpKind {
    /// 35%: read one SUBSCRIBER row.
    GetSubscriberData,
    /// 10%: read SPECIAL_FACILITY + CALL_FORWARDING rows.
    GetNewDestination,
    /// 35%: read one ACCESS_INFO row.
    GetAccessData,
    /// 2%: update SUBSCRIBER bit + SPECIAL_FACILITY data.
    UpdateSubscriberData,
    /// 14%: update SUBSCRIBER location.
    UpdateLocation,
    /// 2%: read SUBSCRIBER + SPECIAL_FACILITY, insert CALL_FORWARDING.
    InsertCallForwarding,
    /// 2%: read SUBSCRIBER, delete CALL_FORWARDING.
    DeleteCallForwarding,
}

impl TatpKind {
    /// Does this transaction type mutate state?
    pub fn is_write(self) -> bool {
        !matches!(
            self,
            TatpKind::GetSubscriberData | TatpKind::GetNewDestination | TatpKind::GetAccessData
        )
    }
}

/// One generated transaction.
#[derive(Clone, Debug)]
pub struct TatpTx {
    /// Transaction type (for per-type stats).
    pub kind: TatpKind,
    /// Read set.
    pub read_set: Vec<TxItem>,
    /// Write set.
    pub write_set: Vec<TxItem>,
}

impl TatpTx {
    /// The native four-table `(read set, write set)` pair for the live
    /// catalog: object ids and keys unchanged, write/insert items
    /// carrying `value_len`-byte stamped values (live tables store real
    /// bytes; see [`crate::dataplane::tx::stamped_sets`]).
    pub fn sets(self, value_len: u32) -> (Vec<TxItem>, Vec<TxItem>) {
        crate::dataplane::tx::stamped_sets(self.read_set, self.write_set, value_len)
    }

    /// **Legacy shim** (pre-catalog): project onto the single-object live
    /// keyspace — keys flattened via [`flat_key`], write/insert items
    /// carrying `value_len`-byte values (the flattened key is stamped
    /// into the first 8 bytes so overwrites are observable). Kept for the
    /// bench's compat mode and equivalence tests; native execution uses
    /// [`TatpTx::sets`].
    pub fn flatten(self, value_len: u32) -> (Vec<TxItem>, Vec<TxItem>) {
        let flat = |item: TxItem, with_value: bool| {
            let key = flat_key(item.obj, item.key);
            let value = if with_value && item.kind != crate::dataplane::tx::WriteKind::Delete {
                let mut v = vec![0u8; value_len as usize];
                let n = v.len().min(8);
                v[..n].copy_from_slice(&key.to_le_bytes()[..n]);
                Some(v)
            } else {
                None
            };
            TxItem { obj: ObjectId(0), key, kind: item.kind, value }
        };
        let reads = self.read_set.into_iter().map(|i| flat(i, false)).collect();
        let writes = self.write_set.into_iter().map(|i| flat(i, true)).collect();
        (reads, writes)
    }
}

/// Workload generator.
#[derive(Clone, Debug)]
pub struct TatpWorkload {
    /// Subscribers in the database.
    pub subscribers: u64,
}

impl TatpWorkload {
    /// Standard-scale generator over `subscribers` subscribers.
    pub fn new(subscribers: u64) -> Self {
        TatpWorkload { subscribers }
    }

    /// TATP's non-uniform subscriber id distribution (NURand-like): the
    /// spec draws `s_id` with a bitwise-OR skew; we use the standard
    /// `(A | B) mod P + 1` construction with A = 2^k-1 scaled to P.
    fn s_id(&self, rng: &mut Pcg64) -> u64 {
        let p = self.subscribers;
        let a = (p.next_power_of_two() / 4).max(1) - 1;
        let x = rng.gen_range(a + 1);
        let y = rng.gen_range(p);
        ((x | y) % p) + 1
    }

    /// Draw the next transaction per the standard mix.
    pub fn next_tx(&self, rng: &mut Pcg64) -> TatpTx {
        let roll = rng.gen_range(100);
        let s = self.s_id(rng);
        let sf_type = rng.gen_range(4) + 1;
        let ai_type = rng.gen_range(4) + 1;
        let start_time = rng.gen_range(3) * 8;
        match roll {
            0..=34 => TatpTx {
                kind: TatpKind::GetSubscriberData,
                read_set: vec![TxItem::read(SUBSCRIBER, s)],
                write_set: vec![],
            },
            35..=44 => TatpTx {
                kind: TatpKind::GetNewDestination,
                read_set: vec![
                    TxItem::read(SPECIAL_FACILITY, sf_key(s, sf_type)),
                    TxItem::read(CALL_FORWARDING, cf_key(s, sf_type, start_time)),
                ],
                write_set: vec![],
            },
            45..=79 => TatpTx {
                kind: TatpKind::GetAccessData,
                read_set: vec![TxItem::read(ACCESS_INFO, sf_key(s, ai_type))],
                write_set: vec![],
            },
            80..=81 => TatpTx {
                kind: TatpKind::UpdateSubscriberData,
                read_set: vec![],
                write_set: vec![
                    TxItem::update(SUBSCRIBER, s),
                    TxItem::update(SPECIAL_FACILITY, sf_key(s, sf_type)),
                ],
            },
            82..=95 => TatpTx {
                kind: TatpKind::UpdateLocation,
                read_set: vec![],
                write_set: vec![TxItem::update(SUBSCRIBER, s)],
            },
            96..=97 => TatpTx {
                kind: TatpKind::InsertCallForwarding,
                read_set: vec![
                    TxItem::read(SUBSCRIBER, s),
                    TxItem::read(SPECIAL_FACILITY, sf_key(s, sf_type)),
                ],
                write_set: vec![TxItem::insert(CALL_FORWARDING, cf_key(s, sf_type, start_time))],
            },
            _ => TatpTx {
                kind: TatpKind::DeleteCallForwarding,
                read_set: vec![TxItem::read(SUBSCRIBER, s)],
                write_set: vec![TxItem::delete(CALL_FORWARDING, cf_key(s, sf_type, start_time))],
            },
        }
    }
}

/// Deterministic initial population (rows per table).
pub struct TatpPopulation {
    /// Subscribers.
    pub subscribers: u64,
}

impl TatpPopulation {
    /// Population for `subscribers`.
    pub fn new(subscribers: u64) -> Self {
        TatpPopulation { subscribers }
    }

    /// Iterate all (object, key) rows to load. Deterministic in `seed`.
    pub fn rows(&self, seed: u64) -> impl Iterator<Item = (ObjectId, u64)> + '_ {
        let mut rng = Pcg64::new(seed, 0xDB);
        (1..=self.subscribers).flat_map(move |s| {
            let mut rows = vec![(SUBSCRIBER, s)];
            let n_ai = rng.gen_range(4) + 1;
            for t in 1..=n_ai {
                rows.push((ACCESS_INFO, sf_key(s, t)));
            }
            let n_sf = rng.gen_range(4) + 1;
            for t in 1..=n_sf {
                rows.push((SPECIAL_FACILITY, sf_key(s, t)));
                let n_cf = rng.gen_range(4); // 0..=3
                for c in 0..n_cf {
                    rows.push((CALL_FORWARDING, cf_key(s, t, c * 8)));
                }
            }
            rows.into_iter()
        })
    }

    /// Expected total row count (rough, for table sizing): 1 + ~2.5 AI +
    /// ~2.5 SF + ~3.75 CF per subscriber.
    pub fn approx_rows(&self) -> u64 {
        self.subscribers * 10
    }

    /// **Legacy shim** (pre-catalog): all rows flattened onto the
    /// single-object live keyspace (see [`flat_key`]). Native loading
    /// feeds [`TatpPopulation::rows`] to `LiveCluster::load_rows`.
    /// Deterministic in `seed`.
    pub fn flat_rows(&self, seed: u64) -> impl Iterator<Item = u64> + '_ {
        self.rows(seed).map(|(obj, key)| flat_key(obj, key))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_matches_paper_fractions() {
        let w = TatpWorkload::new(100_000);
        let mut rng = Pcg64::seeded(7);
        let n = 200_000;
        let mut reads = 0;
        let mut writes = 0;
        let mut inserts_deletes = 0;
        for _ in 0..n {
            let tx = w.next_tx(&mut rng);
            match tx.kind {
                TatpKind::GetSubscriberData | TatpKind::GetNewDestination | TatpKind::GetAccessData => {
                    reads += 1
                }
                TatpKind::UpdateSubscriberData | TatpKind::UpdateLocation => writes += 1,
                TatpKind::InsertCallForwarding | TatpKind::DeleteCallForwarding => {
                    inserts_deletes += 1
                }
            }
        }
        // Paper: "16% of writes and 4% of inserts and deletes".
        let f = |x: i64| x as f64 / n as f64;
        assert!((f(reads) - 0.80).abs() < 0.01, "reads {}", f(reads));
        assert!((f(writes) - 0.16).abs() < 0.01, "writes {}", f(writes));
        assert!((f(inserts_deletes) - 0.04).abs() < 0.01);
    }

    #[test]
    fn subscriber_ids_in_range_and_skewed() {
        let w = TatpWorkload::new(10_000);
        let mut rng = Pcg64::seeded(9);
        let mut low_half = 0;
        for _ in 0..20_000 {
            let tx = w.next_tx(&mut rng);
            for item in tx.read_set.iter().chain(tx.write_set.iter()) {
                if item.obj == SUBSCRIBER {
                    assert!((1..=10_000).contains(&item.key));
                    if item.key <= 5_000 {
                        low_half += 1;
                    }
                }
            }
        }
        assert!(low_half > 0);
    }

    #[test]
    fn key_encodings_disjoint_within_table() {
        // Distinct (s, type) pairs must encode to distinct keys.
        let mut seen = std::collections::HashSet::new();
        for s in 1..=100u64 {
            for t in 1..=4u64 {
                assert!(seen.insert(sf_key(s, t)));
            }
        }
        let mut cf = std::collections::HashSet::new();
        for s in 1..=50u64 {
            for t in 1..=4u64 {
                for st in [0u64, 8, 16] {
                    assert!(cf.insert(cf_key(s, t, st)));
                }
            }
        }
    }

    #[test]
    fn flat_keys_disjoint_across_tables() {
        let mut seen = std::collections::HashSet::new();
        for s in 1..=50u64 {
            assert!(seen.insert(flat_key(SUBSCRIBER, s)));
            for t in 1..=4u64 {
                assert!(seen.insert(flat_key(ACCESS_INFO, sf_key(s, t))));
                assert!(seen.insert(flat_key(SPECIAL_FACILITY, sf_key(s, t))));
                for st in [0u64, 8, 16] {
                    assert!(seen.insert(flat_key(CALL_FORWARDING, cf_key(s, t, st))));
                }
            }
        }
        assert!(seen.iter().all(|&k| k != 0), "0 is the empty-slot marker");
    }

    #[test]
    fn flatten_attaches_values_to_writes_only() {
        let w = TatpWorkload::new(1_000);
        let mut rng = Pcg64::seeded(3);
        let mut saw_write = false;
        for _ in 0..500 {
            let tx = w.next_tx(&mut rng);
            let (reads, writes) = tx.flatten(32);
            for r in &reads {
                assert_eq!(r.obj, ObjectId(0));
                assert!(r.value.is_none(), "read-set items carry no payload");
            }
            for wr in &writes {
                assert_eq!(wr.obj, ObjectId(0));
                match wr.kind {
                    crate::dataplane::tx::WriteKind::Delete => assert!(wr.value.is_none()),
                    _ => {
                        saw_write = true;
                        let v = wr.value.as_ref().expect("live writes carry values");
                        assert_eq!(v.len(), 32);
                        assert_eq!(u64::from_le_bytes(v[..8].try_into().unwrap()), wr.key);
                    }
                }
            }
        }
        assert!(saw_write);
    }

    #[test]
    fn native_sets_keep_objects_and_stamp_write_values() {
        let w = TatpWorkload::new(1_000);
        let mut rng = Pcg64::seeded(5);
        let mut saw_write = false;
        for _ in 0..500 {
            let tx = w.next_tx(&mut rng);
            let kinds: Vec<_> =
                tx.write_set.iter().map(|i| (i.obj, i.key, i.kind)).collect();
            let (reads, writes) = tx.sets(32);
            for r in &reads {
                assert!(r.obj.0 <= 3, "native sets keep table object ids");
                assert!(r.value.is_none(), "read-set items carry no payload");
            }
            assert_eq!(writes.len(), kinds.len());
            for (wr, (obj, key, kind)) in writes.iter().zip(kinds) {
                assert_eq!((wr.obj, wr.key, wr.kind), (obj, key, kind));
                match wr.kind {
                    crate::dataplane::tx::WriteKind::Delete => assert!(wr.value.is_none()),
                    _ => {
                        saw_write = true;
                        let v = wr.value.as_ref().expect("live writes carry values");
                        assert_eq!(v.len(), 32);
                        assert_eq!(u64::from_le_bytes(v[..8].try_into().unwrap()), wr.key);
                        assert_eq!(
                            u32::from_le_bytes(v[8..12].try_into().unwrap()),
                            wr.obj.0,
                            "object id stamped alongside the key"
                        );
                    }
                }
            }
        }
        assert!(saw_write);
    }

    #[test]
    fn live_catalog_sizes_four_tables() {
        let cat = live_catalog(2_000, 32);
        assert_eq!(cat.len(), 4);
        for (cfg, rows) in cat.objects.iter().zip(ROWS_PER_SUBSCRIBER) {
            let cfg = cfg.mica();
            assert!(cfg.buckets.is_power_of_two());
            assert!(cfg.store_values);
            // ~50% occupancy: inline capacity at least the expected rows.
            let capacity = cfg.buckets * cfg.width as u64;
            assert!(capacity as f64 >= 2_000.0 * rows, "table undersized");
        }
        // CALL_FORWARDING is the biggest table, SUBSCRIBER the smallest.
        assert!(cat.objects[3].mica().buckets >= cat.objects[0].mica().buckets);
        // Tiny databases still shard: every table keeps >= 8 buckets.
        assert!(live_catalog(1, 16).objects.iter().all(|c| c.mica().buckets >= 8));
    }

    #[test]
    fn btree_cf_catalog_shapes_and_sizes() {
        use crate::ds::catalog::ObjectKind;
        let cat = live_catalog_btree_cf(2_000, 32);
        assert_eq!(cat.len(), 4);
        for o in 0..3 {
            assert_eq!(cat.objects[o].kind(), ObjectKind::Mica, "table {o}");
        }
        assert_eq!(cat.objects[3].kind(), ObjectKind::BTree);
        // Leaf budget comfortably exceeds the expected CF rows / leaf cap.
        let crate::ds::catalog::ObjectConfig::BTree(b) = &cat.objects[3] else {
            unreachable!()
        };
        let cf_rows = (2_000.0 * ROWS_PER_SUBSCRIBER[3]).ceil() as u64;
        assert!(b.max_leaves * 8 >= cf_rows, "leaf budget too tight for splits");
        // Tiny databases keep a sane floor.
        let tiny = live_catalog_btree_cf(1, 16);
        let crate::ds::catalog::ObjectConfig::BTree(b) = &tiny.objects[3] else {
            unreachable!()
        };
        assert!(b.max_leaves >= 64);
    }

    #[test]
    fn population_deterministic_and_sized() {
        let p = TatpPopulation::new(1000);
        let rows_a: Vec<_> = p.rows(42).collect();
        let rows_b: Vec<_> = p.rows(42).collect();
        assert_eq!(rows_a, rows_b);
        let n = rows_a.len() as u64;
        // 1 + avg 2.5 + avg 2.5 + avg(1.5 per SF * 2.5) = ~9.75/subscriber.
        assert!((6_000..14_000).contains(&n), "rows {n}");
        // Every subscriber row present.
        let subs = rows_a.iter().filter(|(o, _)| *o == SUBSCRIBER).count() as u64;
        assert_eq!(subs, 1000);
    }

    #[test]
    fn transactions_reference_populated_tables() {
        let w = TatpWorkload::new(500);
        let mut rng = Pcg64::seeded(11);
        for _ in 0..1000 {
            let tx = w.next_tx(&mut rng);
            assert!(!tx.read_set.is_empty() || !tx.write_set.is_empty());
            for item in tx.read_set.iter().chain(tx.write_set.iter()) {
                assert!(item.obj.0 <= 3);
            }
        }
    }
}
