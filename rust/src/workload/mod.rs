//! Workloads from the paper's evaluation (§6.1) and beyond.
//!
//! * [`kv`] — *Key-value lookups*: random single-key lookups over the
//!   distributed MICA table, 128-byte transfers.
//! * [`tatp`] — the Telecom Application Transaction Processing benchmark:
//!   seven transaction types over four tables (four catalog objects,
//!   running natively on the live multi-object dataplane), 80% reads /
//!   16% writes / 4% inserts+deletes, run through Storm transactions.
//! * [`smallbank`] — the SmallBank banking benchmark: six transaction
//!   types over three catalog objects with a hot-account skew; much
//!   write-heavier than TATP, stressing the lock/commit volleys and the
//!   abort path.
//! * [`ycsb`] — YCSB Workload E: 95% short range scans / 5% inserts,
//!   the scan-shaped stress for the B-link fence-chain walk
//!   (`LiveClient::lookup_range`), with inserts splitting leaves under
//!   the racing scanners.

pub mod kv;
pub mod smallbank;
pub mod tatp;
pub mod ycsb;

pub use kv::KvWorkload;
pub use smallbank::{SmallBankKind, SmallBankPopulation, SmallBankTx, SmallBankWorkload};
pub use tatp::{TatpKind, TatpPopulation, TatpTx, TatpWorkload};
pub use ycsb::{YcsbEWorkload, YcsbOp};
