//! Workloads from the paper's evaluation (§6.1).
//!
//! * [`kv`] — *Key-value lookups*: random single-key lookups over the
//!   distributed MICA table, 128-byte transfers.
//! * [`tatp`] — the Telecom Application Transaction Processing benchmark:
//!   seven transaction types over four tables, 80% reads / 16% writes /
//!   4% inserts+deletes, run through Storm transactions.

pub mod kv;
pub mod tatp;

pub use kv::KvWorkload;
pub use tatp::{TatpKind, TatpPopulation, TatpTx, TatpWorkload};
