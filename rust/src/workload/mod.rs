//! Workloads from the paper's evaluation (§6.1) and beyond.
//!
//! * [`kv`] — *Key-value lookups*: random single-key lookups over the
//!   distributed MICA table, 128-byte transfers.
//! * [`tatp`] — the Telecom Application Transaction Processing benchmark:
//!   seven transaction types over four tables (four catalog objects,
//!   running natively on the live multi-object dataplane), 80% reads /
//!   16% writes / 4% inserts+deletes, run through Storm transactions.
//! * [`smallbank`] — the SmallBank banking benchmark: six transaction
//!   types over three catalog objects with a hot-account skew; much
//!   write-heavier than TATP, stressing the lock/commit volleys and the
//!   abort path.

pub mod kv;
pub mod smallbank;
pub mod tatp;

pub use kv::KvWorkload;
pub use smallbank::{SmallBankKind, SmallBankPopulation, SmallBankTx, SmallBankWorkload};
pub use tatp::{TatpKind, TatpPopulation, TatpTx, TatpWorkload};
