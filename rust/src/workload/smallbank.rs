//! SmallBank — the banking OLTP benchmark (H-Store / Shore-MT lineage),
//! added for scenario diversity beyond TATP.
//!
//! Three tables keyed by customer id, mapped to three catalog objects:
//! ACCOUNTS (the name→id mapping every transaction consults), SAVINGS
//! and CHECKING (the balances). Six transaction types with the standard
//! mix (SendPayment 25%, the other five 15% each):
//!
//! | type            | reads                         | writes                          |
//! |-----------------|-------------------------------|---------------------------------|
//! | Amalgamate      | ACCOUNTS(a), ACCOUNTS(b)      | SAVINGS(a), CHECKING(a), CHECKING(b) |
//! | Balance         | ACCOUNTS, SAVINGS, CHECKING   | —                               |
//! | DepositChecking | ACCOUNTS                      | CHECKING                        |
//! | SendPayment     | ACCOUNTS(a), ACCOUNTS(b)      | CHECKING(a), CHECKING(b)        |
//! | TransactSavings | ACCOUNTS                      | SAVINGS                         |
//! | WriteCheck      | ACCOUNTS, SAVINGS             | CHECKING                        |
//!
//! Contention comes from the benchmark's hotspot: a configurable
//! fraction of account picks lands in a small hot set, so concurrent
//! clients collide on the hot customers' balance rows — the write-write
//! conflicts the OCC engine must absorb. Unlike TATP (80% reads), four
//! of the six types write, so SmallBank stresses the lock/commit RPC
//! volleys and the abort path much harder.

use crate::dataplane::tx::TxItem;
use crate::ds::api::ObjectId;
use crate::ds::catalog::{buckets_for, CatalogConfig};
use crate::ds::mica::MicaConfig;
use crate::sim::Pcg64;

/// Object id of the ACCOUNTS table.
pub const ACCOUNTS: ObjectId = ObjectId(0);
/// SAVINGS table.
pub const SAVINGS: ObjectId = ObjectId(1);
/// CHECKING table.
pub const CHECKING: ObjectId = ObjectId(2);

/// The six SmallBank transaction types.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SmallBankKind {
    /// 15%: move a customer's savings into their checking, zeroing both
    /// into one row.
    Amalgamate,
    /// 15%: read a customer's total balance.
    Balance,
    /// 15%: deposit into checking.
    DepositChecking,
    /// 25%: transfer checking→checking between two customers.
    SendPayment,
    /// 15%: deposit into savings.
    TransactSavings,
    /// 15%: cash a check against savings+checking, writing checking.
    WriteCheck,
}

impl SmallBankKind {
    /// Does this transaction type mutate state?
    pub fn is_write(self) -> bool {
        !matches!(self, SmallBankKind::Balance)
    }
}

/// One generated transaction.
#[derive(Clone, Debug)]
pub struct SmallBankTx {
    /// Transaction type (for per-type stats).
    pub kind: SmallBankKind,
    /// Read set.
    pub read_set: Vec<TxItem>,
    /// Write set.
    pub write_set: Vec<TxItem>,
}

impl SmallBankTx {
    /// The `(read set, write set)` pair for the live catalog: write items
    /// carry `value_len`-byte stamped values (see
    /// [`crate::dataplane::tx::stamped_sets`]).
    pub fn sets(self, value_len: u32) -> (Vec<TxItem>, Vec<TxItem>) {
        crate::dataplane::tx::stamped_sets(self.read_set, self.write_set, value_len)
    }
}

/// Workload generator.
#[derive(Clone, Debug)]
pub struct SmallBankWorkload {
    /// Customers in the database (accounts are `1..=accounts`).
    pub accounts: u64,
    /// Size of the hot account set (the first `hot_accounts` ids).
    pub hot_accounts: u64,
    /// Percent of account picks drawn from the hot set.
    pub hot_pct: u32,
}

impl SmallBankWorkload {
    /// Standard generator: 10% of accounts are hot and receive 50% of
    /// the picks.
    pub fn new(accounts: u64) -> Self {
        assert!(accounts >= 1);
        SmallBankWorkload { accounts, hot_accounts: (accounts / 10).max(1), hot_pct: 50 }
    }

    /// Pick one account id per the hotspot distribution.
    fn account(&self, rng: &mut Pcg64) -> u64 {
        if rng.gen_range(100) < self.hot_pct as u64 {
            rng.gen_range(self.hot_accounts) + 1
        } else {
            rng.gen_range(self.accounts) + 1
        }
    }

    /// Two distinct account ids (sender/receiver pairs).
    fn account_pair(&self, rng: &mut Pcg64) -> (u64, u64) {
        let a = self.account(rng);
        if self.accounts == 1 {
            return (a, a);
        }
        let mut b = self.account(rng);
        if b == a {
            b = a % self.accounts + 1;
        }
        (a, b)
    }

    /// Draw the next transaction per the standard mix.
    pub fn next_tx(&self, rng: &mut Pcg64) -> SmallBankTx {
        let roll = rng.gen_range(100);
        match roll {
            0..=14 => {
                let (a, b) = self.account_pair(rng);
                SmallBankTx {
                    kind: SmallBankKind::Amalgamate,
                    read_set: vec![TxItem::read(ACCOUNTS, a), TxItem::read(ACCOUNTS, b)],
                    write_set: vec![
                        TxItem::update(SAVINGS, a),
                        TxItem::update(CHECKING, a),
                        TxItem::update(CHECKING, b),
                    ],
                }
            }
            15..=29 => {
                let a = self.account(rng);
                SmallBankTx {
                    kind: SmallBankKind::Balance,
                    read_set: vec![
                        TxItem::read(ACCOUNTS, a),
                        TxItem::read(SAVINGS, a),
                        TxItem::read(CHECKING, a),
                    ],
                    write_set: vec![],
                }
            }
            30..=44 => {
                let a = self.account(rng);
                SmallBankTx {
                    kind: SmallBankKind::DepositChecking,
                    read_set: vec![TxItem::read(ACCOUNTS, a)],
                    write_set: vec![TxItem::update(CHECKING, a)],
                }
            }
            45..=69 => {
                let (a, b) = self.account_pair(rng);
                SmallBankTx {
                    kind: SmallBankKind::SendPayment,
                    read_set: vec![TxItem::read(ACCOUNTS, a), TxItem::read(ACCOUNTS, b)],
                    write_set: vec![TxItem::update(CHECKING, a), TxItem::update(CHECKING, b)],
                }
            }
            70..=84 => {
                let a = self.account(rng);
                SmallBankTx {
                    kind: SmallBankKind::TransactSavings,
                    read_set: vec![TxItem::read(ACCOUNTS, a)],
                    write_set: vec![TxItem::update(SAVINGS, a)],
                }
            }
            _ => {
                let a = self.account(rng);
                SmallBankTx {
                    kind: SmallBankKind::WriteCheck,
                    read_set: vec![TxItem::read(ACCOUNTS, a), TxItem::read(SAVINGS, a)],
                    write_set: vec![TxItem::update(CHECKING, a)],
                }
            }
        }
    }
}

/// Deterministic initial population: one row per customer in each of the
/// three tables.
pub struct SmallBankPopulation {
    /// Customers.
    pub accounts: u64,
}

impl SmallBankPopulation {
    /// Population for `accounts` customers.
    pub fn new(accounts: u64) -> Self {
        SmallBankPopulation { accounts }
    }

    /// Iterate all `(object, key)` rows to load.
    pub fn rows(&self) -> impl Iterator<Item = (ObjectId, u64)> + '_ {
        (1..=self.accounts)
            .flat_map(|c| [(ACCOUNTS, c), (SAVINGS, c), (CHECKING, c)].into_iter())
    }
}

/// The three-object live catalog for a SmallBank database of `accounts`
/// customers (one row per customer per table, ~50% inline occupancy,
/// width-2 buckets).
pub fn live_catalog(accounts: u64, value_len: u32) -> CatalogConfig {
    CatalogConfig::new(
        (0..3)
            .map(|_| MicaConfig {
                buckets: buckets_for(accounts, 2),
                width: 2,
                value_len,
                store_values: true,
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix_matches_standard_fractions() {
        let w = SmallBankWorkload::new(100_000);
        let mut rng = Pcg64::seeded(3);
        let n = 100_000;
        let mut counts: std::collections::HashMap<SmallBankKind, u64> = Default::default();
        for _ in 0..n {
            *counts.entry(w.next_tx(&mut rng).kind).or_insert(0) += 1;
        }
        let f = |k| *counts.get(&k).unwrap_or(&0) as f64 / n as f64;
        assert!((f(SmallBankKind::SendPayment) - 0.25).abs() < 0.01);
        for k in [
            SmallBankKind::Amalgamate,
            SmallBankKind::Balance,
            SmallBankKind::DepositChecking,
            SmallBankKind::TransactSavings,
            SmallBankKind::WriteCheck,
        ] {
            assert!((f(k) - 0.15).abs() < 0.01, "{k:?} fraction {}", f(k));
        }
    }

    #[test]
    fn hotspot_skews_account_picks() {
        let w = SmallBankWorkload::new(10_000);
        let mut rng = Pcg64::seeded(9);
        let mut hot = 0u64;
        let mut total = 0u64;
        for _ in 0..5_000 {
            let tx = w.next_tx(&mut rng);
            for item in tx.read_set.iter().chain(tx.write_set.iter()) {
                assert!((1..=10_000).contains(&item.key));
                total += 1;
                if item.key <= w.hot_accounts {
                    hot += 1;
                }
            }
        }
        // 50% of picks from the hot 10%: far above the uniform share.
        assert!(hot * 3 > total, "hot fraction {hot}/{total}");
    }

    #[test]
    fn transactions_reference_the_three_tables_consistently() {
        let w = SmallBankWorkload::new(500);
        let mut rng = Pcg64::seeded(1);
        for _ in 0..2_000 {
            let tx = w.next_tx(&mut rng);
            assert!(!tx.read_set.is_empty(), "every type consults ACCOUNTS");
            assert!(tx.read_set.iter().any(|i| i.obj == ACCOUNTS));
            for item in tx.read_set.iter().chain(tx.write_set.iter()) {
                assert!(item.obj.0 <= 2);
            }
            // Balance sheets: writes only touch balance tables.
            for wr in &tx.write_set {
                assert!(wr.obj == SAVINGS || wr.obj == CHECKING);
            }
            assert_eq!(tx.kind.is_write(), !tx.write_set.is_empty());
            if tx.kind == SmallBankKind::SendPayment {
                assert_eq!(tx.write_set.len(), 2);
                if w.accounts > 1 {
                    assert_ne!(
                        tx.write_set[0].key, tx.write_set[1].key,
                        "payments move between distinct accounts"
                    );
                }
            }
        }
    }

    #[test]
    fn sets_attach_stamped_values_to_writes_only() {
        let w = SmallBankWorkload::new(200);
        let mut rng = Pcg64::seeded(4);
        for _ in 0..200 {
            let (reads, writes) = w.next_tx(&mut rng).sets(24);
            for r in &reads {
                assert!(r.value.is_none());
            }
            for wr in &writes {
                let v = wr.value.as_ref().expect("updates carry values");
                assert_eq!(v.len(), 24);
                assert_eq!(u64::from_le_bytes(v[..8].try_into().unwrap()), wr.key);
                assert_eq!(u32::from_le_bytes(v[8..12].try_into().unwrap()), wr.obj.0);
            }
        }
    }

    #[test]
    fn population_covers_every_table() {
        let p = SmallBankPopulation::new(100);
        let rows: Vec<_> = p.rows().collect();
        assert_eq!(rows.len(), 300);
        for obj in [ACCOUNTS, SAVINGS, CHECKING] {
            assert_eq!(rows.iter().filter(|(o, _)| *o == obj).count(), 100);
        }
        let cat = live_catalog(100, 16);
        assert_eq!(cat.len(), 3);
        assert!(cat.objects.iter().all(|c| {
            let m = c.mica();
            m.buckets * m.width as u64 >= 100
        }));
    }
}
