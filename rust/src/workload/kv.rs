//! Key-value lookup workload (paper §6.1).
//!
//! Uniform-random single-key lookups over a keyspace partitioned across
//! the cluster by the hash owner function. By default only *remote* keys
//! are sampled (the paper's microbenchmark measures the network dataplane,
//! not local hash-table reads); optionally a Zipfian skew can be applied
//! for contention studies beyond the paper.

use crate::ds::mica::owner_of;
use crate::sim::{Pcg64, Zipf};

/// Key-sampling workload state (one per coroutine or thread).
#[derive(Clone, Debug)]
pub struct KvWorkload {
    /// Total keys across the cluster (keys are `1..=total`).
    pub total_keys: u64,
    /// Number of nodes (for owner exclusion).
    pub nodes: u32,
    /// Sample keys owned by this node too?
    pub include_local: bool,
    /// Optional Zipfian skew (None = uniform).
    zipf: Option<Zipf>,
}

impl KvWorkload {
    /// Uniform workload over `total_keys` keys.
    pub fn uniform(total_keys: u64, nodes: u32) -> Self {
        KvWorkload { total_keys, nodes, include_local: false, zipf: None }
    }

    /// Zipfian-skewed variant.
    pub fn zipfian(total_keys: u64, nodes: u32, theta: f64) -> Self {
        KvWorkload {
            total_keys,
            nodes,
            include_local: false,
            zipf: Some(Zipf::new(total_keys, theta)),
        }
    }

    /// Sample the next key for a client on `my_node`.
    pub fn next_key(&self, my_node: u32, rng: &mut Pcg64) -> u64 {
        loop {
            let key = match &self.zipf {
                Some(z) => z.sample(rng) + 1,
                None => rng.gen_range(self.total_keys) + 1,
            };
            if self.include_local || self.nodes == 1 || owner_of(key, self.nodes) != my_node {
                return key;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_in_range_and_remote() {
        let w = KvWorkload::uniform(10_000, 8);
        let mut rng = Pcg64::seeded(1);
        for _ in 0..5_000 {
            let k = w.next_key(3, &mut rng);
            assert!((1..=10_000).contains(&k));
            assert_ne!(owner_of(k, 8), 3);
        }
    }

    #[test]
    fn include_local_allows_own_keys() {
        let mut w = KvWorkload::uniform(10_000, 4);
        w.include_local = true;
        let mut rng = Pcg64::seeded(2);
        let mut local = 0;
        for _ in 0..10_000 {
            if owner_of(w.next_key(0, &mut rng), 4) == 0 {
                local += 1;
            }
        }
        // Roughly a quarter should be local.
        assert!((1500..3500).contains(&local), "local {local}");
    }

    #[test]
    fn single_node_does_not_spin() {
        let w = KvWorkload::uniform(100, 1);
        let mut rng = Pcg64::seeded(3);
        let k = w.next_key(0, &mut rng);
        assert!((1..=100).contains(&k));
    }

    #[test]
    fn zipf_skews_toward_hot_keys() {
        let w = KvWorkload::zipfian(100_000, 4, 0.99);
        let mut rng = Pcg64::seeded(4);
        let mut head = 0;
        for _ in 0..20_000 {
            if w.next_key(0, &mut rng) <= 1_000 {
                head += 1;
            }
        }
        assert!(head > 5_000, "zipf head {head}");
    }
}
