//! `storm` — CLI for the Storm reproduction.
//!
//! ```text
//! storm bench <fig1|fig4|fig5|fig6|fig7|table5|physseg|breakeven|ablations|all> [--full] [--threads N]
//! storm run --system <storm-rpc|storm-oversub|storm-perfect|erpc|erpc-nocc|farm|farm-locked|lite|lite-sync>
//!           [--nodes N] [--threads N] [--coros N] [--tatp] [--full]
//! storm verify-runtime [artifacts-dir]    # load + execute the AOT artifacts via PJRT
//! ```
//!
//! Argument parsing is hand-rolled: the build environment is offline and
//! vendored, so the binary depends only on `xla` and `anyhow`.

use anyhow::{bail, Result};

use storm::bench::{ablations, breakeven, fig1, fig4, fig5, fig6, fig7, physseg, table5, BenchOpts};
use storm::cluster::{SimConfig, StormMode, SystemKind, WorkloadKind, World};
use storm::sim::{MICRO, MILLI};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("bench") => cmd_bench(&args[1..]),
        Some("run") => cmd_run(&args[1..]),
        Some("verify-runtime") => cmd_verify_runtime(&args[1..]),
        Some("help") | None => {
            print_help();
            Ok(())
        }
        Some(other) => {
            print_help();
            bail!("unknown command {other:?}")
        }
    }
}

fn print_help() {
    println!(
        "storm — reproduction of 'Storm: a fast transactional dataplane for remote data structures'\n\
         \n\
         USAGE:\n\
           storm bench <fig1|fig4|fig5|fig6|fig7|table5|physseg|breakeven|ablations|all> [--full] [--threads N]\n\
           storm run --system <name> [--nodes N] [--threads N] [--coros N] [--tatp] [--full]\n\
           storm verify-runtime [artifacts-dir]\n\
         \n\
         systems: storm-rpc storm-oversub storm-perfect erpc erpc-nocc farm farm-locked lite lite-sync"
    );
}

fn flag(args: &[String], name: &str) -> bool {
    args.iter().any(|a| a == name)
}

fn opt_u32(args: &[String], name: &str) -> Option<u32> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1)).and_then(|v| v.parse().ok())
}

fn cmd_bench(args: &[String]) -> Result<()> {
    let which = args.first().map(|s| s.as_str()).unwrap_or("all");
    let opts = BenchOpts {
        quick: !flag(args, "--full"),
        threads: opt_u32(args, "--threads").unwrap_or(8),
    };
    let run = |name: &str, opts: BenchOpts| {
        match name {
            "fig1" => {
                fig1(opts.quick);
            }
            "fig4" => {
                fig4(opts);
            }
            "fig5" => {
                fig5(opts);
            }
            "fig6" => {
                fig6(opts);
            }
            "fig7" => {
                fig7(opts);
            }
            "table5" => {
                table5(opts);
            }
            "physseg" => {
                physseg(opts);
            }
            "breakeven" => {
                breakeven(opts.quick);
            }
            "ablations" => {
                ablations(opts);
            }
            _ => {}
        }
        println!();
    };
    if which == "all" {
        for name in
            ["fig1", "fig4", "fig5", "fig6", "fig7", "table5", "physseg", "breakeven", "ablations"]
        {
            run(name, opts);
        }
    } else {
        run(which, opts);
    }
    Ok(())
}

fn parse_system(name: &str) -> Result<SystemKind> {
    Ok(match name {
        "storm-rpc" => SystemKind::Storm(StormMode::RpcOnly),
        "storm-oversub" => SystemKind::Storm(StormMode::OneTwoSided),
        "storm-perfect" => SystemKind::Storm(StormMode::Perfect),
        "erpc" => SystemKind::Erpc { congestion_control: true },
        "erpc-nocc" => SystemKind::Erpc { congestion_control: false },
        "farm" => SystemKind::Farm { locked_qp_sharing: false },
        "farm-locked" => SystemKind::Farm { locked_qp_sharing: true },
        "lite" => SystemKind::Lite { async_ops: true },
        "lite-sync" => SystemKind::Lite { async_ops: false },
        other => bail!("unknown system {other:?}"),
    })
}

fn cmd_run(args: &[String]) -> Result<()> {
    let system = parse_system(
        args.iter()
            .position(|a| a == "--system")
            .and_then(|i| args.get(i + 1))
            .map(|s| s.as_str())
            .unwrap_or("storm-oversub"),
    )?;
    let nodes = opt_u32(args, "--nodes").unwrap_or(8);
    let mut cfg = SimConfig::new(system, nodes);
    cfg.threads = opt_u32(args, "--threads").unwrap_or(8);
    cfg.coros = opt_u32(args, "--coros").unwrap_or(8);
    if flag(args, "--tatp") {
        cfg.workload = WorkloadKind::Tatp { subscribers_per_node: 5_000 };
    }
    if flag(args, "--full") {
        cfg.warmup = MILLI;
        cfg.measure = 8 * MILLI;
        cfg.keys_per_node = 60_000;
    } else {
        cfg.warmup = 200 * MICRO;
        cfg.measure = MILLI;
        cfg.keys_per_node = 20_000;
    }
    let report = World::new(cfg).run();
    println!("{}", report.row());
    println!(
        "events={} ({:.1} M events/s host)  sim_time={:.2} ms  ud_drops={} retrans={}",
        report.events,
        report.events_per_sec() / 1e6,
        report.sim_ns as f64 / 1e6,
        report.ud_drops,
        report.retransmits
    );
    Ok(())
}

fn cmd_verify_runtime(args: &[String]) -> Result<()> {
    let dir = args.first().map(|s| s.as_str()).unwrap_or("artifacts");
    storm::runtime::verify(dir)
}
