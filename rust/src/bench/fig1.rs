//! Figure 1 — CX3 vs CX4 vs CX5 read throughput vs. connection count —
//! and the §3.4 read-vs-UD breakeven study.
//!
//! This is the paper's two-machine microbenchmark: one machine issues
//! random 64-byte one-sided reads over 20 GB of the other's memory (2 MB
//! pages; plus a CX5 variant with 4 KB pages and 1024 memory regions), with
//! the number of RC connections swept from 1 to ~10k. It exercises the NIC
//! model directly — PUs, state cache, connection penalty — without the full
//! cluster world, exactly like the paper isolates the NIC.

use crate::mem::{PageSize, RegionMode, RegionTable};
use crate::nic::{Nic, NicGen, NicOp, NicSide};
use crate::sim::{EventQueue, Nanos, Pcg64, SECOND};

/// One measured point.
#[derive(Clone, Debug)]
pub struct Fig1Point {
    /// Series label (e.g. "CX5", "4KB,1024MR (CX5)").
    pub series: String,
    /// Connections used round-robin.
    pub connections: u32,
    /// Measured million reads per second.
    pub mreads_per_sec: f64,
}

/// 20 GB of registered memory split over `mrs` regions (Fig. 1 setup).
pub struct MemLayout {
    regions: RegionTable,
    region_lens: Vec<u64>,
}

impl MemLayout {
    /// 20 GB in `mrs` regions with the given page size.
    fn new(total: u64, mrs: u32, page: PageSize) -> Self {
        let mut regions = RegionTable::new();
        let per = total / mrs as u64;
        let mut region_lens = Vec::new();
        for _ in 0..mrs {
            regions.register(per, RegionMode::Virtual(page));
            region_lens.push(per);
        }
        MemLayout { regions, region_lens }
    }

    /// Total MTT entries across regions.
    fn total_mtt_entries(&self) -> u64 {
        self.regions.mtt_entries()
    }

    /// Random read target: (mpt id, first mtt entry).
    fn sample(&self, rng: &mut Pcg64, len: u64) -> (u64, Option<(u64, u32)>) {
        let mr = rng.gen_index(self.region_lens.len());
        let off = rng.gen_range(self.region_lens[mr] - len);
        let key = crate::mem::MrKey(mr as u32);
        let mut it = self.regions.mtt_entries_for(key, off, len);
        let first = it.next();
        (mr as u64, first.map(|f| (f, 1)))
    }
}

/// Pipeline stage of an in-flight microbenchmark op.
#[derive(Clone, Copy, Debug)]
enum Stage {
    /// Requester/client transmit.
    Tx { conn: u64 },
    /// Remote NIC services the request.
    Rx { conn: u64 },
    /// Server transmits the RPC response (UD benchmark only).
    TxResp { conn: u64 },
    /// Requester/client receives response / raises CQE.
    Cqe { conn: u64 },
}

/// Closed-loop 2-node read microbenchmark: `window` outstanding reads
/// across `conns` connections; returns Mreads/s. Each pipeline stage is a
/// separate event so NIC occupancy is charged in true time order.
pub fn read_microbench(
    gen: NicGen,
    conns: u32,
    layout: &mut MemLayout,
    read_bytes: u32,
    duration: Nanos,
) -> f64 {
    let params = gen.params();
    let mut requester = Nic::new(params.clone());
    let mut responder = Nic::new(params.clone());
    let wire: Nanos = 400; // fixed RoCE-ish one-way for the microbench
    // Enough outstanding reads to saturate the PUs; connections are
    // sampled uniformly so the hot-slot and cache miss rates converge to
    // their steady state independent of the window size.
    let window = (params.pus * 16).max(64);
    let mut rng = Pcg64::seeded(0xF16_1 + conns as u64);
    // Steady-state: warm QP contexts and memory-translation state the way
    // seconds of real benchmarking would (LRU keeps what fits).
    requester.prewarm(0..conns as u64, std::iter::empty(), std::iter::empty());
    responder.prewarm(
        0..conns as u64,
        0..layout.region_lens.len() as u64,
        0..layout.total_mtt_entries(),
    );
    let mut q: EventQueue<Stage> = EventQueue::new();
    for i in 0..window {
        let conn = rng.gen_range(conns as u64);
        q.push_at(i as Nanos % 1024, Stage::Tx { conn });
    }
    let warmup = duration / 5;
    let mut measured: u64 = 0;
    while let Some(ev) = q.pop() {
        let now = ev.at;
        if now >= duration {
            break;
        }
        match ev.event {
            Stage::Tx { conn } => {
                let op = NicOp::requester(NicSide::ReqTx, conn, 16);
                let (f, _) = requester.process(now, &op);
                q.push_at(f + wire, Stage::Rx { conn });
            }
            Stage::Rx { conn } => {
                let (mpt, mtt) = layout.sample(&mut rng, read_bytes as u64);
                let op = NicOp {
                    side: NicSide::RespRead,
                    qp: conn,
                    len: read_bytes,
                    mpt: Some(mpt),
                    mtt,
                    extra_ns: 0.0,
                    extra_hold_ns: 0.0,
                };
                let (f, _) = responder.process(now, &op);
                q.push_at(f + wire, Stage::Cqe { conn });
            }
            Stage::Cqe { conn } => {
                let op = NicOp::requester(NicSide::ReqRxCqe, conn, 0);
                let (f, _) = requester.process(now, &op);
                if f >= warmup && f < duration {
                    measured += 1;
                }
                // Reissue on a fresh random connection.
                let next = rng.gen_range(conns as u64);
                q.push_at(f, Stage::Tx { conn: next });
            }
            Stage::TxResp { .. } => unreachable!("reads have no response tx"),
        }
    }
    measured as f64 * (SECOND as f64 / (duration - warmup) as f64) / 1e6
}

/// UD send/recv RPC microbenchmark (the §3.4 comparator): request +
/// response datagrams, one QP per side; returns M RPCs/s.
pub fn ud_rpc_microbench(gen: NicGen, duration: Nanos) -> f64 {
    let params = gen.params();
    let mut client = Nic::new(params.clone());
    let mut server = Nic::new(params.clone());
    let wire: Nanos = 400;
    let window = (params.pus * 16).max(64);
    let extra = 0.4 * params.pu_service_ns;
    let mut q: EventQueue<Stage> = EventQueue::new();
    for i in 0..window {
        q.push_at(i as Nanos * 7, Stage::Tx { conn: 1 });
    }
    let warmup = duration / 5;
    let mut measured: u64 = 0;
    while let Some(ev) = q.pop() {
        let now = ev.at;
        if now >= duration {
            break;
        }
        match ev.event {
            Stage::Tx { conn } => {
                let mut tx = NicOp::requester(NicSide::ReqTx, conn, 64);
                tx.extra_ns = extra;
                let (f, _) = client.process(now, &tx);
                q.push_at(f + wire, Stage::Rx { conn: 2 });
            }
            Stage::Rx { conn } => {
                let rx = NicOp::requester(NicSide::RespRecvUd, conn, 64);
                let (f, _) = server.process(now, &rx);
                q.push_at(f, Stage::TxResp { conn });
            }
            Stage::TxResp { conn } => {
                let mut tx = NicOp::requester(NicSide::ReqTx, conn, 128);
                tx.extra_ns = extra;
                let (f, _) = server.process(now, &tx);
                q.push_at(f + wire, Stage::Cqe { conn: 1 });
            }
            Stage::Cqe { conn } => {
                let rx = NicOp::requester(NicSide::RespRecvUd, conn, 128);
                let (f, _) = client.process(now, &rx);
                if f >= warmup && f < duration {
                    measured += 1;
                }
                q.push_at(f, Stage::Tx { conn });
            }
        }
    }
    measured as f64 * (SECOND as f64 / (duration - warmup) as f64) / 1e6
}

/// One-call probe: read throughput for a (generation, connections, memory
/// layout) point. Used by tests, the breakeven study and debugging.
pub fn read_probe(gen: NicGen, conns: u32, mrs: u32, page: PageSize, duration: Nanos) -> f64 {
    let mut layout = MemLayout::new(20u64 << 30, mrs, page);
    read_microbench(gen, conns, &mut layout, 64, duration)
}

/// Run the Figure 1 sweep. `quick` shortens the per-point duration.
pub fn fig1(quick: bool) -> Vec<Fig1Point> {
    let duration: Nanos = if quick { 400_000 } else { 2_000_000 };
    let conn_counts: &[u32] = &[1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024, 2048, 5000, 10_000];
    let total = 20u64 << 30;
    let mut out = Vec::new();
    println!("# Figure 1: per-machine read throughput (Mreads/s) vs #connections");
    println!("# 64B random reads over 20GB; 2MB pages unless noted");
    print!("{:<18}", "series");
    for c in conn_counts {
        print!("{c:>9}");
    }
    println!();
    let series: Vec<(String, NicGen, u32, PageSize)> = vec![
        ("CX3".into(), NicGen::Cx3, 1, PageSize::Huge2M),
        ("CX4".into(), NicGen::Cx4, 1, PageSize::Huge2M),
        ("CX5".into(), NicGen::Cx5, 1, PageSize::Huge2M),
        ("4KB,1024MR(CX5)".into(), NicGen::Cx5, 1024, PageSize::Small4K),
    ];
    for (name, gen, mrs, page) in series {
        print!("{name:<18}");
        for &c in conn_counts {
            let mut layout = MemLayout::new(total, mrs, page);
            let m = read_microbench(gen, c, &mut layout, 64, duration);
            print!("{m:>9.1}");
            out.push(Fig1Point { series: name.clone(), connections: c, mreads_per_sec: m });
        }
        println!();
    }
    out
}

/// §3.4: how many connections until one-sided reads fall to the UD
/// send/recv RPC rate on CX5 (paper: 2500–3800).
pub fn breakeven(quick: bool) -> (f64, u32) {
    let duration: Nanos = if quick { 400_000 } else { 2_000_000 };
    let ud = ud_rpc_microbench(NicGen::Cx5, duration);
    println!("# Breakeven study (CX5): UD send/recv RPC rate = {ud:.1} M/s");
    let total = 20u64 << 30;
    let mut crossing = 0;
    for c in [64u32, 128, 256, 512, 1024, 1536, 2048, 2560, 3072, 3584, 4096, 5120, 8192] {
        let mut layout = MemLayout::new(total, 1, PageSize::Huge2M);
        let reads = read_microbench(NicGen::Cx5, c, &mut layout, 64, duration);
        let marker = if reads < ud && crossing == 0 { " <-- breakeven" } else { "" };
        if reads < ud && crossing == 0 {
            crossing = c;
        }
        println!("conns={c:>5}  reads={reads:>7.1} M/s  ud_rpc={ud:>6.1} M/s{marker}");
    }
    (ud, crossing)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cx5_peak_and_floor_match_paper() {
        let total = 20u64 << 30;
        let mut layout = MemLayout::new(total, 1, PageSize::Huge2M);
        let peak = read_microbench(NicGen::Cx5, 8, &mut layout, 64, 400_000);
        // Paper: close to 40M reads/s at low connection counts...
        assert!((30.0..50.0).contains(&peak), "CX5 peak {peak}");
        let mut layout = MemLayout::new(total, 1, PageSize::Huge2M);
        let floor = read_microbench(NicGen::Cx5, 10_000, &mut layout, 64, 400_000);
        // ...and ~10 reqs/us once the cache is useless.
        assert!((6.0..15.0).contains(&floor), "CX5 floor {floor}");
    }

    #[test]
    fn fig1_drops_match_paper() {
        let total = 20u64 << 30;
        for (gen, want_drop, tol) in [
            (NicGen::Cx3, 0.83, 0.10),
            (NicGen::Cx4, 0.42, 0.10),
            (NicGen::Cx5, 0.32, 0.10),
        ] {
            let mut l8 = MemLayout::new(total, 1, PageSize::Huge2M);
            let at8 = read_microbench(gen, 8, &mut l8, 64, 400_000);
            let mut l64 = MemLayout::new(total, 1, PageSize::Huge2M);
            let at64 = read_microbench(gen, 64, &mut l64, 64, 400_000);
            let drop = 1.0 - at64 / at8;
            assert!(
                (drop - want_drop).abs() < tol,
                "{:?}: drop {drop:.2} want {want_drop}",
                gen
            );
        }
    }

    #[test]
    fn small_pages_many_regions_hurt() {
        let total = 20u64 << 30;
        let mut good = MemLayout::new(total, 1, PageSize::Huge2M);
        let mut bad = MemLayout::new(total, 1024, PageSize::Small4K);
        let g = read_microbench(NicGen::Cx5, 16, &mut good, 64, 400_000);
        let b = read_microbench(NicGen::Cx5, 16, &mut bad, 64, 400_000);
        assert!(b < g * 0.8, "4KB/1024MR {b} vs 2MB/1MR {g}");
    }

    #[test]
    fn breakeven_in_paper_range() {
        let (ud, crossing) = breakeven(true);
        assert!(ud > 5.0, "ud rate {ud}");
        assert!(
            (1_000..6_000).contains(&crossing),
            "breakeven at {crossing} conns (paper: 2500-3800)"
        );
    }
}
