//! World-based figure harnesses: Figures 4–7, Table 5, the physical
//! segment study (§6.2.5) and the design ablations.

use crate::cluster::{RunReport, SimConfig, StormMode, SystemKind, WorkloadKind, World};
use crate::fabric::FabricKind;
use crate::mem::PageSize;
use crate::sim::{MICRO, MILLI};

/// Harness options.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    /// Shorter windows + smaller tables for CI-speed runs.
    pub quick: bool,
    /// Threads per machine (the paper runs up to 20).
    pub threads: u32,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { quick: true, threads: 8 }
    }
}

impl BenchOpts {
    fn apply(&self, cfg: &mut SimConfig) {
        cfg.threads = self.threads;
        if self.quick {
            cfg.keys_per_node = 12_000;
            cfg.warmup = 150 * MICRO;
            cfg.measure = 800 * MICRO;
        } else {
            cfg.keys_per_node = 60_000;
            cfg.warmup = 500 * MICRO;
            cfg.measure = 4 * MILLI;
        }
    }
}

/// Storm configuration constructors matching the paper's curves.
fn storm_cfg(mode: StormMode, nodes: u32, opts: &BenchOpts) -> SimConfig {
    let mut cfg = SimConfig::new(SystemKind::Storm(mode), nodes);
    opts.apply(&mut cfg);
    match mode {
        // Plain "Storm": same memory, small table -> high occupancy and
        // chains; every lookup is an RPC anyway.
        StormMode::RpcOnly => {
            cfg.occupancy = 1.6;
        }
        // "Storm (oversub)": oversized width-1 table, low collision rate.
        StormMode::OneTwoSided => {
            cfg.occupancy = 0.45;
            cfg.bucket_width = 1;
        }
        // "Storm (perfect)": oversub + fully warmed address cache.
        StormMode::Perfect => {
            cfg.occupancy = 0.6;
            cfg.bucket_width = 1;
        }
    }
    cfg
}

fn print_series(title: &str, rows: &[RunReport]) {
    println!("# {title}");
    for r in rows {
        println!("{}", r.row());
    }
}

/// Figure 4: Storm configurations, KV lookups, 4–32 nodes.
pub fn fig4(opts: BenchOpts) -> Vec<RunReport> {
    let node_counts = [4u32, 8, 16, 24, 32];
    let mut out = Vec::new();
    for mode in [StormMode::RpcOnly, StormMode::OneTwoSided, StormMode::Perfect] {
        for &n in &node_counts {
            let cfg = storm_cfg(mode, n, &opts);
            out.push(World::new(cfg).run());
        }
    }
    print_series("Figure 4: Storm / Storm(oversub) / Storm(perfect), KV lookups", &out);
    out
}

/// Figure 5: Storm(oversub) vs eRPC(±CC) vs Lockfree_FaRM vs Async_LITE,
/// 4–16 nodes (eRPC capped at 16 nodes in the paper by RQ provisioning).
pub fn fig5(opts: BenchOpts) -> Vec<RunReport> {
    let node_counts = [4u32, 8, 12, 16];
    let systems = [
        SystemKind::Storm(StormMode::OneTwoSided),
        SystemKind::Erpc { congestion_control: true },
        SystemKind::Erpc { congestion_control: false },
        SystemKind::Farm { locked_qp_sharing: false },
        SystemKind::Lite { async_ops: true },
    ];
    let mut out = Vec::new();
    for sys in systems {
        for &n in &node_counts {
            let cfg = match sys {
                SystemKind::Storm(m) => storm_cfg(m, n, &opts),
                other => {
                    let mut c = SimConfig::new(other, n);
                    opts.apply(&mut c);
                    c
                }
            };
            // The paper's eRPC deployment is limited by UD receive-queue
            // provisioning: peers * window must fit the RQ.
            if let SystemKind::Erpc { .. } = sys {
                let needed = (n - 1) * cfg.threads * cfg.coros;
                assert!(
                    needed <= cfg.host.recv_pool_capacity,
                    "eRPC cannot provision {n} nodes (the paper stopped at 16)"
                );
            }
            out.push(World::new(cfg).run());
        }
    }
    print_series("Figure 5: Storm vs eRPC vs Lockfree_FaRM vs Async_LITE, KV lookups", &out);
    out
}

/// Figure 6: TATP on Storm vs Storm(oversub), 4–32 nodes.
pub fn fig6(opts: BenchOpts) -> Vec<RunReport> {
    let node_counts = [4u32, 8, 16, 24, 32];
    let subscribers = if opts.quick { 2_000 } else { 10_000 };
    let mut out = Vec::new();
    for mode in [StormMode::RpcOnly, StormMode::OneTwoSided] {
        for &n in &node_counts {
            let mut cfg = storm_cfg(mode, n, &opts);
            cfg.workload = WorkloadKind::Tatp { subscribers_per_node: subscribers };
            out.push(World::new(cfg).run());
        }
    }
    print_series("Figure 6: TATP transactions/s per machine", &out);
    out
}

/// Figure 7: emulated clusters 32→128 virtual nodes on 32 machines,
/// Storm(perfect), 20 vs 10 threads.
pub fn fig7(opts: BenchOpts) -> Vec<RunReport> {
    let virtual_nodes = [32u32, 64, 96, 128];
    let mut out = Vec::new();
    for threads in [20u32, 10] {
        for &v in &virtual_nodes {
            let mut o = opts;
            o.threads = threads;
            let mut cfg = storm_cfg(StormMode::Perfect, 32, &o);
            cfg.conn_multiplier = v / 32;
            // Emulation fixes total compute: same machines, more state.
            out.push(World::new(cfg).run());
        }
    }
    println!("# Figure 7: Storm(perfect), emulated cluster sizes (32 physical nodes)");
    for (i, r) in out.iter().enumerate() {
        let threads = if i < 4 { 20 } else { 10 };
        let v = virtual_nodes[i % 4];
        println!("threads={threads:<3} virtual_nodes={v:<4} {}", r.row());
    }
    out
}

/// One point of the connection-scaling sweep: a transport variant at one
/// active-QP working-set size on one NIC generation.
#[derive(Clone, Debug)]
pub struct ConnScalePoint {
    /// NIC generation label (`cx4` / `cx5`).
    pub nic: &'static str,
    /// Transport variant (`static_rc` / `static_ud` / `adaptive` /
    /// `rc_qp_share`).
    pub variant: &'static str,
    /// Threads multiplexed per RC connection (1 for unshared variants).
    pub qp_share: u32,
    /// Cluster size the clients fan out to.
    pub fanout_nodes: u32,
    /// Fig. 7 connection multiplier at this point.
    pub conn_multiplier: u32,
    /// RC connections a client machine holds (the swept axis).
    pub conns_per_machine: u64,
    /// The run.
    pub report: RunReport,
}

impl ConnScalePoint {
    /// JSON row for `BENCH_live.json`'s `connection_scaling` array.
    pub fn json(&self) -> String {
        format!(
            concat!(
                "{{\"nic\": \"{}\", \"variant\": \"{}\", \"qp_share\": {}, ",
                "\"fanout_nodes\": {}, \"conn_multiplier\": {}, ",
                "\"conns_per_machine\": {}, \"per_machine_mops\": {:.4}, ",
                "\"nic_hit_rate\": {:.4}, \"active_qps\": {}, ",
                "\"nic_evictions\": {}, \"demotions\": {}, \"promotions\": {}, ",
                "\"ud_destinations\": {}}}"
            ),
            self.nic,
            self.variant,
            self.qp_share,
            self.fanout_nodes,
            self.conn_multiplier,
            self.conns_per_machine,
            self.report.per_machine_mops,
            self.report.nic_hit_rate,
            self.report.active_qps,
            self.report.nic_evictions,
            self.report.demotions,
            self.report.promotions,
            self.report.ud_destinations,
        )
    }
}

/// The connection-scaling sweep (the adaptive-transport tentpole bench):
/// per-machine throughput vs the RC connection working set, swept over
/// three-plus decades of active-QP counts (rack scale → emulated hundreds
/// of nodes, Fig. 7 style: `fanout_nodes` × `conn_multiplier`), across
/// two NIC generations and four transport variants — static RC (the
/// seed), static UD (the eRPC position), the adaptive RC→UD controller,
/// and RC with QP multiplexing (`qp_share` ∈ {2, 4}).
pub fn connection_scaling(opts: BenchOpts) -> Vec<ConnScalePoint> {
    use crate::nic::NicGen;
    use crate::transport::topology::Topology;
    use crate::transport::TransportPolicy;

    // The swept axis: (cluster fan-out, Fig. 7 multiplier). With 4 client
    // threads the unshared RC connection count per machine runs 24 →
    // 32640 — a bit over three decades.
    const POINTS: [(u32, u32); 5] = [(4, 1), (16, 2), (64, 4), (256, 8), (256, 16)];
    const VARIANTS: [(&str, TransportPolicy, u32); 5] = [
        ("static_rc", TransportPolicy::StaticRc, 1),
        ("static_ud", TransportPolicy::StaticUd, 1),
        ("adaptive", TransportPolicy::Adaptive, 1),
        ("rc_qp_share", TransportPolicy::StaticRc, 2),
        ("rc_qp_share", TransportPolicy::StaticRc, 4),
    ];
    let mut out = Vec::new();
    for (gen, nic_name) in [(NicGen::Cx4, "cx4"), (NicGen::Cx5, "cx5")] {
        for (variant, policy, share) in VARIANTS {
            for (fanout, mult) in POINTS {
                let mut o = opts;
                o.threads = 4;
                let mut cfg = storm_cfg(StormMode::Perfect, 2, &o);
                cfg.nic = gen;
                cfg.fanout_nodes = fanout;
                cfg.conn_multiplier = mult;
                cfg.transport = policy;
                cfg.qp_share = share;
                // Small per-node tables and short windows: the sweep's
                // cost is dominated by cluster construction at 256 nodes.
                cfg.keys_per_node = 1_000;
                cfg.warmup = 100 * MICRO;
                cfg.measure = 400 * MICRO;
                let topo = Topology {
                    nodes: cfg.total_nodes(),
                    threads: cfg.threads,
                    conn_multiplier: mult,
                    qp_share: share,
                };
                let report = World::new(cfg).run();
                out.push(ConnScalePoint {
                    nic: nic_name,
                    variant,
                    qp_share: share,
                    fanout_nodes: fanout,
                    conn_multiplier: mult,
                    conns_per_machine: topo.rc_conns_per_machine(),
                    report,
                });
            }
        }
    }
    println!("# connection scaling: throughput vs RC connection working set");
    for p in &out {
        println!(
            "conn_scale nic={} variant={:<11} share={} conns={:>6}  {:>7.3} Mops  hit {:.3}  demote {}  promote {}",
            p.nic,
            p.variant,
            p.qp_share,
            p.conns_per_machine,
            p.report.per_machine_mops,
            p.report.nic_hit_rate,
            p.report.demotions,
            p.report.promotions,
        );
    }
    out
}

/// Table 5: unloaded round-trip latencies on CX4 IB and CX4 RoCE.
pub fn table5(opts: BenchOpts) -> Vec<RunReport> {
    let mut out = Vec::new();
    println!("# Table 5: unloaded RTT (us). Paper CX4(IB): RR 1.8, RPC 2.7, eRPC 2.7, FaRM 2.1, LITE 5.8");
    println!("#                Paper CX4(RoCE): RR 2.8, RPC 3.9, eRPC 3.6, FaRM 3.0, LITE 6.4");
    for fabric in [FabricKind::IbEdr, FabricKind::Roce100] {
        let fname = match fabric {
            FabricKind::IbEdr => "CX4(IB)",
            FabricKind::Roce100 => "CX4(RoCE)",
            FabricKind::Roce40 => "CX3(RoCE)",
        };
        let systems: Vec<(&str, SystemKind)> = vec![
            ("Storm(RR)", SystemKind::Storm(StormMode::Perfect)),
            ("Storm(RPC)", SystemKind::Storm(StormMode::RpcOnly)),
            ("eRPC", SystemKind::Erpc { congestion_control: true }),
            ("FaRM", SystemKind::Farm { locked_qp_sharing: false }),
            ("LITE", SystemKind::Lite { async_ops: true }),
        ];
        for (name, sys) in systems {
            let mut cfg = match sys {
                SystemKind::Storm(m) => storm_cfg(m, 2, &opts),
                other => {
                    let mut c = SimConfig::new(other, 2);
                    opts.apply(&mut c);
                    c
                }
            };
            // Unloaded: one thread, one outstanding op.
            cfg.threads = 1;
            cfg.coros = 1;
            cfg.fabric = fabric;
            cfg.keys_per_node = 4_000;
            let mut r = World::new(cfg).run();
            r.label = format!("{fname} {name}");
            println!("{:<22} mean RTT = {:>6.2} us", r.label, r.mean_ns / 1_000.0);
            out.push(r);
        }
    }
    out
}

/// §6.2.5: physical segments vs 4 KB pages on a PB-scale memory (emulated
/// by 4 KB pages over the full dataset, so the MTT dwarfs the NIC cache).
pub fn physseg(opts: BenchOpts) -> Vec<RunReport> {
    let mut out = Vec::new();
    for (name, use_physseg) in [("4KB pages", false), ("physical segment", true)] {
        let mut cfg = storm_cfg(StormMode::Perfect, 8, &opts);
        cfg.nic = crate::nic::NicGen::Cx5;
        cfg.page_size = PageSize::Small4K;
        cfg.physseg = use_physseg;
        // More data per node to blow up the 4 KB MTT.
        cfg.keys_per_node = if opts.quick { 60_000 } else { 200_000 };
        let mut r = World::new(cfg).run();
        r.label = format!("Storm {name}");
        out.push(r);
    }
    println!("# §6.2.5 physical segments (paper: +32% throughput)");
    for r in &out {
        println!("{}", r.row());
    }
    let gain = out[1].per_machine_mops / out[0].per_machine_mops;
    println!("physseg gain: {gain:.2}x (paper: 1.32x)");
    out
}

/// Design ablations the paper argues in §4/§6:
/// FaRM QP-sharing locks, write-imm vs send/recv RPC.
pub fn ablations(opts: BenchOpts) -> Vec<RunReport> {
    let mut out = Vec::new();
    // (a) QP-sharing locks (original FaRM shares few QPs among all
    // threads) vs lock-free (the paper's improved Lockfree_FaRM).
    for locked in [false, true] {
        let mut cfg = SimConfig::new(SystemKind::Farm { locked_qp_sharing: locked }, 8);
        opts.apply(&mut cfg);
        cfg.host.farm_qp_group = cfg.threads; // one shared QP per machine
        out.push(World::new(cfg).run());
    }
    // (b) Storm RPC path: write_with_imm vs send/recv.
    for sendrecv in [false, true] {
        let mut cfg = storm_cfg(StormMode::RpcOnly, 8, &opts);
        cfg.rpc_via_sendrecv = sendrecv;
        let mut r = World::new(cfg).run();
        if sendrecv {
            r.label = "Storm(rpc,send/recv)".into();
        }
        out.push(r);
    }
    print_series("Ablations: QP locks; write-imm vs send/recv RPC", &out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> BenchOpts {
        BenchOpts { quick: true, threads: 4 }
    }

    #[test]
    fn fig4_ordering_holds() {
        let rows = fig4(opts());
        // rows: 5x RpcOnly, 5x OneTwo, 5x Perfect; compare at 32 nodes.
        let rpc = &rows[4];
        let oversub = &rows[9];
        let perfect = &rows[14];
        assert!(oversub.per_machine_mops > rpc.per_machine_mops);
        assert!(perfect.per_machine_mops > oversub.per_machine_mops);
        // Paper: oversub 1.7x, perfect 2.2x over Storm at 32 nodes.
        let r1 = oversub.per_machine_mops / rpc.per_machine_mops;
        let r2 = perfect.per_machine_mops / rpc.per_machine_mops;
        assert!((1.2..2.6).contains(&r1), "oversub/rpc = {r1:.2} (paper 1.7)");
        assert!((1.5..3.2).contains(&r2), "perfect/rpc = {r2:.2} (paper 2.2)");
    }

    #[test]
    fn ablation_locks_hurt_and_sendrecv_slower() {
        let rows = ablations(opts());
        assert!(
            rows[0].per_machine_mops > rows[1].per_machine_mops,
            "lock-free {} vs locked {}",
            rows[0].per_machine_mops,
            rows[1].per_machine_mops
        );
        assert!(
            rows[2].per_machine_mops > rows[3].per_machine_mops,
            "write-imm {} vs send/recv {}",
            rows[2].per_machine_mops,
            rows[3].per_machine_mops
        );
    }
}
