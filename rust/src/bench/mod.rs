//! Figure/table harnesses: one runner per table and figure in the paper's
//! evaluation (see DESIGN.md §5 for the index).
//!
//! Each runner builds the configurations the paper describes, executes the
//! simulator, prints rows shaped like the paper's plot series, and returns
//! the reports so tests and `cargo bench` targets can assert on the shapes
//! (who wins, by roughly what factor, where crossovers fall).

pub mod fig1;
pub mod figures;

pub use fig1::{fig1, breakeven, Fig1Point};
pub use figures::{
    ablations, connection_scaling, fig4, fig5, fig6, fig7, physseg, table5, BenchOpts,
    ConnScalePoint,
};
