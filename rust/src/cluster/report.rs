//! Run summaries: simulator reports (consumed by the figure harnesses and
//! the CLI) and live-cluster service counters.

use crate::dataplane::tx::{AbortReason, TxOutcome};
use crate::sim::Nanos;

/// Per-[`AbortReason`] abort tallies of a transactional run. An abort
/// *storm* (a retry loop melting throughput) is only diagnosable when the
/// reasons are visible: a wall of `LockConflict` means write contention,
/// `ValidationVersion`/`ValidationLocked` mean read-write interleaving,
/// `ValidationMoved` means structural churn (B-link splits racing
/// readers), `Unsupported` means a client is aiming transactions at
/// a backend kind outside the opcode set, and `PrimaryFenced` means the
/// run hit a failover window (a deposed primary refusing writes while
/// clients re-routed to the promoted backup).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AbortCounts {
    /// Execution-phase write-lock conflicts.
    pub lock_conflict: u64,
    /// Read-set item version changed between execute and validate.
    pub validation_version: u64,
    /// Read-set item was foreign-locked at validation.
    pub validation_locked: u64,
    /// Read-set item moved (stale address / a split relocated the key).
    pub validation_moved: u64,
    /// A lock/commit opcode answered with the typed dispatch error.
    pub unsupported: u64,
    /// A lock/replication opcode hit a fenced (deposed or unrecovered)
    /// node; the retry routes to the promoted backup.
    pub primary_fenced: u64,
}

impl AbortCounts {
    /// Tally one abort.
    pub fn record(&mut self, reason: AbortReason) {
        match reason {
            AbortReason::LockConflict => self.lock_conflict += 1,
            AbortReason::ValidationVersion => self.validation_version += 1,
            AbortReason::ValidationLocked => self.validation_locked += 1,
            AbortReason::ValidationMoved => self.validation_moved += 1,
            AbortReason::Unsupported => self.unsupported += 1,
            AbortReason::PrimaryFenced => self.primary_fenced += 1,
        }
    }

    /// Tally a transaction outcome (commits are ignored).
    pub fn record_outcome(&mut self, outcome: &TxOutcome) {
        if let TxOutcome::Aborted(reason) = outcome {
            self.record(*reason);
        }
    }

    /// Merge another tally in (per-client tallies roll up per run).
    pub fn merge(&mut self, other: &AbortCounts) {
        self.lock_conflict += other.lock_conflict;
        self.validation_version += other.validation_version;
        self.validation_locked += other.validation_locked;
        self.validation_moved += other.validation_moved;
        self.unsupported += other.unsupported;
        self.primary_fenced += other.primary_fenced;
    }

    /// Total aborts across all reasons.
    pub fn total(&self) -> u64 {
        self.lock_conflict
            + self.validation_version
            + self.validation_locked
            + self.validation_moved
            + self.unsupported
            + self.primary_fenced
    }

    /// The JSON object benches embed in `BENCH_live.json`.
    pub fn json(&self) -> String {
        format!(
            concat!(
                "{{\"lock_conflict\": {}, \"validation_version\": {}, ",
                "\"validation_locked\": {}, \"validation_moved\": {}, ",
                "\"unsupported\": {}, \"primary_fenced\": {}}}"
            ),
            self.lock_conflict,
            self.validation_version,
            self.validation_locked,
            self.validation_moved,
            self.unsupported,
            self.primary_fenced,
        )
    }
}

/// Per-lane RPC service counts from a live cluster run:
/// `per_lane[node][lane]` is the number of requests the given bucket-range
/// shard's event loop served. Returned by `LiveCluster::shutdown` so shard
/// imbalance (hot buckets pinning one lane) is visible in reports.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LiveServed {
    /// Requests served, indexed `[node][lane]`.
    pub per_lane: Vec<Vec<u64>>,
    /// Envelopes each shard reactor forwarded to a sibling shard of its
    /// node over the cross-shard SPSC rings, indexed `[node][lane]`.
    /// Forwarding is the slow path (misrouted lane-0 control traffic);
    /// a forwarded count rivaling the served count means clients are
    /// not posting to owning lanes.
    pub forwarded: Vec<Vec<u64>>,
    /// Final adaptive transaction windows of the run's clients, one entry
    /// per client that reported via [`LiveServed::record_tx_window`]
    /// (empty when the run had no transactional clients). The live
    /// scheduler grows the window while commits stay clean and shrinks it
    /// on sustained aborts, so these values show where each client's
    /// concurrency settled.
    pub tx_windows: Vec<u32>,
    /// Per-reason abort tallies rolled up from the run's clients via
    /// [`LiveServed::record_aborts`] (each `LiveClient` counts its own;
    /// see `LiveClient::abort_counts`).
    pub aborts: AbortCounts,
    /// Per-transaction-class abort tallies (`("tatp/GetSubscriberData",
    /// counts)`, `("smallbank/WriteCheck", counts)`, …) recorded via
    /// [`LiveServed::record_class_aborts`]. Per-client tallies say *who*
    /// aborted; these say *which workload shape* did — a failover window
    /// shows up as `primary_fenced` concentrated in the write classes.
    pub class_aborts: Vec<(String, AbortCounts)>,
}

impl LiveServed {
    /// Record one client's final adaptive transaction window.
    pub fn record_tx_window(&mut self, window: u32) {
        self.tx_windows.push(window);
    }

    /// Roll one client's per-reason abort tallies into the run's.
    pub fn record_aborts(&mut self, counts: &AbortCounts) {
        self.aborts.merge(counts);
    }

    /// Roll a per-transaction-class tally into the run's (merging with
    /// an existing class of the same name, so multiple clients running
    /// the same mix aggregate).
    pub fn record_class_aborts(&mut self, class: &str, counts: &AbortCounts) {
        match self.class_aborts.iter_mut().find(|(name, _)| name == class) {
            Some((_, existing)) => existing.merge(counts),
            None => self.class_aborts.push((class.to_string(), *counts)),
        }
    }

    /// A class's rolled-up tally, if any client recorded it.
    pub fn class_aborts(&self, class: &str) -> Option<&AbortCounts> {
        self.class_aborts.iter().find(|(name, _)| name == class).map(|(_, c)| c)
    }

    /// The per-class JSON object benches embed in `BENCH_live.json`
    /// (`{"tatp/UpdateLocation": {...}, ...}`; classes in recording
    /// order).
    pub fn class_json(&self) -> String {
        let rows: Vec<String> = self
            .class_aborts
            .iter()
            .map(|(name, counts)| format!("\"{}\": {}", name, counts.json()))
            .collect();
        format!("{{{}}}", rows.join(", "))
    }

    /// Total served per node.
    pub fn node_totals(&self) -> Vec<u64> {
        self.per_lane.iter().map(|lanes| lanes.iter().sum()).collect()
    }

    /// Cluster-wide cross-shard forwards (see [`LiveServed::forwarded`]).
    pub fn total_forwarded(&self) -> u64 {
        self.forwarded.iter().flatten().sum()
    }

    /// Cluster-wide total.
    pub fn total(&self) -> u64 {
        self.per_lane.iter().flatten().sum()
    }

    /// Busiest-lane to mean-lane ratio across all lanes (1.0 = perfectly
    /// balanced; 0.0 when no lane served anything).
    pub fn imbalance(&self) -> f64 {
        let lanes: Vec<u64> = self.per_lane.iter().flatten().copied().collect();
        let total: u64 = lanes.iter().sum();
        if total == 0 || lanes.is_empty() {
            return 0.0;
        }
        let mean = total as f64 / lanes.len() as f64;
        *lanes.iter().max().unwrap() as f64 / mean
    }
}

impl std::fmt::Display for LiveServed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (node, lanes) in self.per_lane.iter().enumerate() {
            let total: u64 = lanes.iter().sum();
            let fwd: u64 = self.forwarded.get(node).map(|l| l.iter().sum()).unwrap_or(0);
            write!(f, "node {node}: {total} served, {fwd} forwarded, lanes {lanes:?}")?;
            if node + 1 < self.per_lane.len() {
                writeln!(f)?;
            }
        }
        Ok(())
    }
}

/// Aggregated results of one simulated run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Label (system + config) for tables.
    pub label: String,
    /// Machines simulated.
    pub nodes: u32,
    /// Completed operations (KV lookups or committed transactions) inside
    /// the measurement window, cluster-wide.
    pub ops: u64,
    /// Throughput per machine, Mops/s.
    pub per_machine_mops: f64,
    /// Mean operation latency (ns).
    pub mean_ns: f64,
    /// Median latency (ns).
    pub p50_ns: u64,
    /// Tail latency (ns).
    pub p99_ns: u64,
    /// Transactions aborted (TATP).
    pub aborts: u64,
    /// One-sided reads issued per completed op.
    pub reads_per_op: f64,
    /// RPCs issued per completed op.
    pub rpcs_per_op: f64,
    /// Average NIC state-cache hit rate across machines.
    pub nic_hit_rate: f64,
    /// Average NIC PU utilization.
    pub nic_utilization: f64,
    /// UD datagrams dropped at receive queues.
    pub ud_drops: u64,
    /// UD retransmissions.
    pub retransmits: u64,
    /// Events processed (simulator perf accounting).
    pub events: u64,
    /// Wall-clock the simulation took (ns, host time).
    pub wall_ns: u64,
    /// Simulated time covered (ns).
    pub sim_ns: Nanos,
}

impl RunReport {
    /// Abort rate among attempted transactions.
    pub fn abort_rate(&self) -> f64 {
        let attempts = self.ops + self.aborts;
        if attempts == 0 {
            0.0
        } else {
            self.aborts as f64 / attempts as f64
        }
    }

    /// Simulator speed in events per host second.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.events as f64 * 1e9 / self.wall_ns as f64
    }

    /// One-line table row.
    pub fn row(&self) -> String {
        format!(
            "{:<28} nodes={:<3} {:>8.2} Mops/machine  mean={:>7.0}ns p50={:>7}ns p99={:>8}ns  r/op={:.2} rpc/op={:.2} abort={:.3} nic_hit={:.3}",
            self.label,
            self.nodes,
            self.per_machine_mops,
            self.mean_ns,
            self.p50_ns,
            self.p99_ns,
            self.reads_per_op,
            self.rpcs_per_op,
            self.abort_rate(),
            self.nic_hit_rate,
        )
    }
}

impl std::fmt::Display for RunReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.row())
    }
}
