//! Run summaries: simulator reports (consumed by the figure harnesses and
//! the CLI) and live-cluster service counters.
//!
//! # Observability
//!
//! The live dataplane measures itself at two sites, and this module owns
//! the containers both report into:
//!
//! * **Client side** — each `LiveClient` owns a fixed set of
//!   [`ClientLatency`] histograms (allocated once at client build, never
//!   on the hot path) plus a [`crate::sim::stats::WindowSeries`]
//!   throughput meter over epoch-synced ~10 ms windows. Timestamps are
//!   taken per *doorbell batch*, not per item — one monotonic clock pair
//!   brackets the posted volley and the measured duration is recorded
//!   once per op it covered — so instrumentation adds no allocation and
//!   amortizes the clock reads the same way the doorbell amortizes
//!   posts. Latency is recorded along three axes: opcode (one-sided
//!   `read` / whole `lookup` / `tx_rpc`), backend kind (MICA, B-link,
//!   hopscotch), and transaction phase
//!   ([`crate::dataplane::tx::PHASE_LABELS`]). Per-client instances
//!   merge into one [`ClientLatency`] / series at report time.
//!
//! * **Server side** — each shard reactor keeps [`LaneGauges`]: how
//!   many envelopes a drain burst found waiting (queue depth sampled at
//!   drain), how often the reactor parked and was woken, and the
//!   deepest control-job backlog it drained. The gauges ride back
//!   through `LiveCluster::shutdown` into [`LiveServed::gauges`], so
//!   reactor idling and lane imbalance are diagnosable, not just
//!   countable.
//!
//! `scripts/bench.sh` emits the merged client view as `latency` rows
//! (p50/p99/p999/mean/max per opcode × kind × phase) and
//! `throughput_series` rows in `BENCH_live.json`;
//! `scripts/check_bench_schema.sh` gates the emit shape in CI.

use crate::dataplane::tx::{AbortReason, TxOutcome, PHASE_LABELS};
use crate::sim::stats::{Histogram, WindowSeries};
use crate::sim::Nanos;

/// Per-[`AbortReason`] abort tallies of a transactional run. An abort
/// *storm* (a retry loop melting throughput) is only diagnosable when the
/// reasons are visible: a wall of `LockConflict` means write contention,
/// `ValidationVersion`/`ValidationLocked` mean read-write interleaving,
/// `ValidationMoved` means structural churn (B-link splits racing
/// readers), `Unsupported` means a client is aiming transactions at
/// a backend kind outside the opcode set, and `PrimaryFenced` means the
/// run hit a failover window (a deposed primary refusing writes while
/// clients re-routed to the promoted backup).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AbortCounts {
    /// Execution-phase write-lock conflicts.
    pub lock_conflict: u64,
    /// Read-set item version changed between execute and validate.
    pub validation_version: u64,
    /// Read-set item was foreign-locked at validation.
    pub validation_locked: u64,
    /// Read-set item moved (stale address / a split relocated the key).
    pub validation_moved: u64,
    /// A lock/commit opcode answered with the typed dispatch error.
    pub unsupported: u64,
    /// A lock/replication opcode hit a fenced (deposed or unrecovered)
    /// node; the retry routes to the promoted backup.
    pub primary_fenced: u64,
}

impl AbortCounts {
    /// Tally one abort.
    pub fn record(&mut self, reason: AbortReason) {
        match reason {
            AbortReason::LockConflict => self.lock_conflict += 1,
            AbortReason::ValidationVersion => self.validation_version += 1,
            AbortReason::ValidationLocked => self.validation_locked += 1,
            AbortReason::ValidationMoved => self.validation_moved += 1,
            AbortReason::Unsupported => self.unsupported += 1,
            AbortReason::PrimaryFenced => self.primary_fenced += 1,
        }
    }

    /// Tally a transaction outcome (commits are ignored).
    pub fn record_outcome(&mut self, outcome: &TxOutcome) {
        if let TxOutcome::Aborted(reason) = outcome {
            self.record(*reason);
        }
    }

    /// Merge another tally in (per-client tallies roll up per run).
    pub fn merge(&mut self, other: &AbortCounts) {
        self.lock_conflict += other.lock_conflict;
        self.validation_version += other.validation_version;
        self.validation_locked += other.validation_locked;
        self.validation_moved += other.validation_moved;
        self.unsupported += other.unsupported;
        self.primary_fenced += other.primary_fenced;
    }

    /// Total aborts across all reasons.
    pub fn total(&self) -> u64 {
        self.lock_conflict
            + self.validation_version
            + self.validation_locked
            + self.validation_moved
            + self.unsupported
            + self.primary_fenced
    }

    /// The JSON object benches embed in `BENCH_live.json`.
    pub fn json(&self) -> String {
        format!(
            concat!(
                "{{\"lock_conflict\": {}, \"validation_version\": {}, ",
                "\"validation_locked\": {}, \"validation_moved\": {}, ",
                "\"unsupported\": {}, \"primary_fenced\": {}}}"
            ),
            self.lock_conflict,
            self.validation_version,
            self.validation_locked,
            self.validation_moved,
            self.unsupported,
            self.primary_fenced,
        )
    }
}

/// Backend-kind axis labels for latency rows, in the index order
/// [`ClientLatency`] uses (`mica`, `btree`, `hopscotch`, `queue`).
pub const KIND_LABELS: [&str; 4] = ["mica", "btree", "hopscotch", "queue"];

/// The fixed latency-histogram set a live client owns: one distribution
/// per opcode × backend kind for the lookup path and one per transaction
/// phase for the RPC path. All histograms are allocated here, once, at
/// client build — recording on the hot path touches preallocated buckets
/// only (see the module-level Observability notes).
#[derive(Clone, Debug, Default)]
pub struct ClientLatency {
    /// One-sided doorbell-read latency per backend kind
    /// (indexed by [`KIND_LABELS`]; the `queue` row times peek reads).
    pub read: [Histogram; 4],
    /// Whole-lookup latency (start machine through drained completion,
    /// RPC fallback legs included) per backend kind.
    pub lookup: [Histogram; 4],
    /// Transaction phase-volley latency (first post of the phase through
    /// the completion that drains it), indexed by [`PHASE_LABELS`].
    pub tx_phase: [Histogram; 4],
}

impl ClientLatency {
    /// Merge another client's histograms into this one (report-time
    /// roll-up across a run's clients).
    pub fn merge(&mut self, other: &ClientLatency) {
        for (a, b) in self.read.iter_mut().zip(other.read.iter()) {
            a.merge(b);
        }
        for (a, b) in self.lookup.iter_mut().zip(other.lookup.iter()) {
            a.merge(b);
        }
        for (a, b) in self.tx_phase.iter_mut().zip(other.tx_phase.iter()) {
            a.merge(b);
        }
    }

    /// Total recorded samples across every histogram.
    pub fn total_samples(&self) -> u64 {
        let sum = |hs: &[Histogram]| hs.iter().map(Histogram::count).sum::<u64>();
        sum(&self.read) + sum(&self.lookup) + sum(&self.tx_phase)
    }

    /// Every row of the fixed latency schema as
    /// `(opcode, kind, phase, histogram)`. Rows with zero samples are
    /// included — the schema is stable regardless of workload mix.
    pub fn rows(&self) -> Vec<(&'static str, &'static str, &'static str, &Histogram)> {
        let mut out =
            Vec::with_capacity(self.read.len() + self.lookup.len() + self.tx_phase.len());
        for (i, h) in self.read.iter().enumerate() {
            out.push(("read", KIND_LABELS[i], "-", h));
        }
        for (i, h) in self.lookup.iter().enumerate() {
            out.push(("lookup", KIND_LABELS[i], "-", h));
        }
        for (i, h) in self.tx_phase.iter().enumerate() {
            out.push(("tx_rpc", "all", PHASE_LABELS[i], h));
        }
        out
    }

    /// The Table-5-style JSON array benches embed under the `latency`
    /// key: one row per opcode × kind × phase with p50/p99/p999/mean/max
    /// (nanoseconds) and the sample count.
    pub fn json(&self) -> String {
        let rows: Vec<String> = self
            .rows()
            .iter()
            .map(|(op, kind, phase, h)| {
                format!(
                    concat!(
                        "{{\"op\": \"{}\", \"kind\": \"{}\", \"phase\": \"{}\", ",
                        "\"count\": {}, \"p50_ns\": {}, \"p99_ns\": {}, ",
                        "\"p999_ns\": {}, \"mean_ns\": {:.1}, \"max_ns\": {}}}"
                    ),
                    op,
                    kind,
                    phase,
                    h.count(),
                    h.p50(),
                    h.p99(),
                    h.p999(),
                    h.mean(),
                    h.max(),
                )
            })
            .collect();
        format!("[{}]", rows.join(", "))
    }
}

/// The JSON array benches embed under the `throughput_series` key: one
/// row per elapsed window with its start offset and completion count.
pub fn throughput_series_json(series: &WindowSeries) -> String {
    let window_ms = series.window_ns() / 1_000_000;
    let rows: Vec<String> = series
        .windows()
        .iter()
        .enumerate()
        .map(|(i, &ops)| format!("{{\"t_ms\": {}, \"ops\": {}}}", i as u64 * window_ms, ops))
        .collect();
    format!("[{}]", rows.join(", "))
}

/// Per-reactor idle/backlog gauges, sampled on the reactor's own thread
/// (no shared counters on the request path) and returned through
/// `LiveCluster::shutdown` into [`LiveServed::gauges`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LaneGauges {
    /// Drain bursts that found at least one envelope waiting (each is
    /// one queue-depth sample).
    pub drains: u64,
    /// Sum of sampled queue depths (envelopes found per drain burst);
    /// `depth_sum / drains` is the mean backlog a burst cleared.
    pub depth_sum: u64,
    /// Deepest single drain burst observed.
    pub depth_max: u64,
    /// Times the reactor exhausted its idle spins and parked.
    pub parks: u64,
    /// Times a parked reactor was woken by a doorbell (parks that ended
    /// with work waiting rather than by timeout).
    pub wakes: u64,
    /// Deepest control-job backlog a single `drain_jobs` pass cleared.
    pub jobs_max: u64,
}

impl LaneGauges {
    /// Mean envelopes cleared per drain burst (0 when never drained).
    pub fn mean_depth(&self) -> f64 {
        if self.drains == 0 {
            0.0
        } else {
            self.depth_sum as f64 / self.drains as f64
        }
    }
}

/// Per-lane RPC service counts from a live cluster run:
/// `per_lane[node][lane]` is the number of requests the given bucket-range
/// shard's event loop served. Returned by `LiveCluster::shutdown` so shard
/// imbalance (hot buckets pinning one lane) is visible in reports.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LiveServed {
    /// Requests served, indexed `[node][lane]`.
    pub per_lane: Vec<Vec<u64>>,
    /// Envelopes each shard reactor forwarded to a sibling shard of its
    /// node over the cross-shard SPSC rings, indexed `[node][lane]`.
    /// Forwarding is the slow path (misrouted lane-0 control traffic);
    /// a forwarded count rivaling the served count means clients are
    /// not posting to owning lanes.
    pub forwarded: Vec<Vec<u64>>,
    /// Final adaptive transaction windows of the run's clients, one entry
    /// per client that reported via [`LiveServed::record_tx_window`]
    /// (empty when the run had no transactional clients). The live
    /// scheduler grows the window while commits stay clean and shrinks it
    /// on sustained aborts, so these values show where each client's
    /// concurrency settled.
    pub tx_windows: Vec<u32>,
    /// Per-reason abort tallies rolled up from the run's clients via
    /// [`LiveServed::record_aborts`] (each `LiveClient` counts its own;
    /// see `LiveClient::abort_counts`).
    pub aborts: AbortCounts,
    /// Per-transaction-class abort tallies (`("tatp/GetSubscriberData",
    /// counts)`, `("smallbank/WriteCheck", counts)`, …) recorded via
    /// [`LiveServed::record_class_aborts`]. Per-client tallies say *who*
    /// aborted; these say *which workload shape* did — a failover window
    /// shows up as `primary_fenced` concentrated in the write classes.
    pub class_aborts: Vec<(String, AbortCounts)>,
    /// Per-reactor idle/backlog gauges, indexed `[node][lane]` like
    /// [`LiveServed::per_lane`]. Empty for drivers that predate the
    /// gauges (the simulator's `RunReport` path).
    pub gauges: Vec<Vec<LaneGauges>>,
}

impl LiveServed {
    /// Record one client's final adaptive transaction window.
    pub fn record_tx_window(&mut self, window: u32) {
        self.tx_windows.push(window);
    }

    /// Roll one client's per-reason abort tallies into the run's.
    pub fn record_aborts(&mut self, counts: &AbortCounts) {
        self.aborts.merge(counts);
    }

    /// Roll a per-transaction-class tally into the run's (merging with
    /// an existing class of the same name, so multiple clients running
    /// the same mix aggregate).
    pub fn record_class_aborts(&mut self, class: &str, counts: &AbortCounts) {
        match self.class_aborts.iter_mut().find(|(name, _)| name == class) {
            Some((_, existing)) => existing.merge(counts),
            None => self.class_aborts.push((class.to_string(), *counts)),
        }
    }

    /// A class's rolled-up tally, if any client recorded it.
    pub fn class_aborts(&self, class: &str) -> Option<&AbortCounts> {
        self.class_aborts.iter().find(|(name, _)| name == class).map(|(_, c)| c)
    }

    /// The per-class JSON object benches embed in `BENCH_live.json`
    /// (`{"tatp/UpdateLocation": {...}, ...}`; classes in recording
    /// order).
    pub fn class_json(&self) -> String {
        let rows: Vec<String> = self
            .class_aborts
            .iter()
            .map(|(name, counts)| format!("\"{}\": {}", name, counts.json()))
            .collect();
        format!("{{{}}}", rows.join(", "))
    }

    /// Total served per node.
    pub fn node_totals(&self) -> Vec<u64> {
        self.per_lane.iter().map(|lanes| lanes.iter().sum()).collect()
    }

    /// Cluster-wide cross-shard forwards (see [`LiveServed::forwarded`]).
    pub fn total_forwarded(&self) -> u64 {
        self.forwarded.iter().flatten().sum()
    }

    /// Cluster-wide total.
    pub fn total(&self) -> u64 {
        self.per_lane.iter().flatten().sum()
    }

    /// Cluster-wide reactor parks (see [`LaneGauges::parks`]).
    pub fn total_parks(&self) -> u64 {
        self.gauges.iter().flatten().map(|g| g.parks).sum()
    }

    /// Cluster-wide queue-depth samples taken at drain (see
    /// [`LaneGauges::drains`]); zero means the gauges never ran.
    pub fn total_drains(&self) -> u64 {
        self.gauges.iter().flatten().map(|g| g.drains).sum()
    }

    /// Busiest-lane to mean-lane ratio across all lanes (1.0 = perfectly
    /// balanced; 0.0 when no lane served anything).
    pub fn imbalance(&self) -> f64 {
        let lanes: Vec<u64> = self.per_lane.iter().flatten().copied().collect();
        let total: u64 = lanes.iter().sum();
        if total == 0 || lanes.is_empty() {
            return 0.0;
        }
        let mean = total as f64 / lanes.len() as f64;
        *lanes.iter().max().unwrap() as f64 / mean
    }
}

impl std::fmt::Display for LiveServed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (node, lanes) in self.per_lane.iter().enumerate() {
            let total: u64 = lanes.iter().sum();
            let fwd: u64 = self.forwarded.get(node).map(|l| l.iter().sum()).unwrap_or(0);
            write!(f, "node {node}: {total} served, {fwd} forwarded, lanes {lanes:?}")?;
            if node + 1 < self.per_lane.len() {
                writeln!(f)?;
            }
        }
        Ok(())
    }
}

/// Aggregated results of one simulated run.
#[derive(Clone, Debug)]
pub struct RunReport {
    /// Label (system + config) for tables.
    pub label: String,
    /// Machines simulated.
    pub nodes: u32,
    /// Completed operations (KV lookups or committed transactions) inside
    /// the measurement window, cluster-wide.
    pub ops: u64,
    /// Throughput per machine, Mops/s.
    pub per_machine_mops: f64,
    /// Mean operation latency (ns).
    pub mean_ns: f64,
    /// Median latency (ns).
    pub p50_ns: u64,
    /// Tail latency (ns).
    pub p99_ns: u64,
    /// Transactions aborted (TATP).
    pub aborts: u64,
    /// One-sided reads issued per completed op.
    pub reads_per_op: f64,
    /// RPCs issued per completed op.
    pub rpcs_per_op: f64,
    /// Average NIC state-cache hit rate across machines.
    pub nic_hit_rate: f64,
    /// Average NIC PU utilization.
    pub nic_utilization: f64,
    /// UD datagrams dropped at receive queues.
    pub ud_drops: u64,
    /// UD retransmissions.
    pub retransmits: u64,
    /// Peak active-QP estimate across machines (NIC two-epoch tracker).
    pub active_qps: u32,
    /// NIC state-cache capacity evictions, summed across machines.
    pub nic_evictions: u64,
    /// Adaptive transport: RC→UD demotions, summed across client nodes.
    pub demotions: u64,
    /// Adaptive transport: UD→RC promotions, summed across client nodes.
    pub promotions: u64,
    /// Destinations still served over UD at the end of the run, summed
    /// across client nodes.
    pub ud_destinations: u32,
    /// Events processed (simulator perf accounting).
    pub events: u64,
    /// Wall-clock the simulation took (ns, host time).
    pub wall_ns: u64,
    /// Simulated time covered (ns).
    pub sim_ns: Nanos,
}

impl RunReport {
    /// Abort rate among attempted transactions.
    pub fn abort_rate(&self) -> f64 {
        let attempts = self.ops + self.aborts;
        if attempts == 0 {
            0.0
        } else {
            self.aborts as f64 / attempts as f64
        }
    }

    /// Simulator speed in events per host second.
    pub fn events_per_sec(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.events as f64 * 1e9 / self.wall_ns as f64
    }

    /// One-line table row.
    pub fn row(&self) -> String {
        format!(
            "{:<28} nodes={:<3} {:>8.2} Mops/machine  mean={:>7.0}ns p50={:>7}ns p99={:>8}ns  r/op={:.2} rpc/op={:.2} abort={:.3} nic_hit={:.3}",
            self.label,
            self.nodes,
            self.per_machine_mops,
            self.mean_ns,
            self.p50_ns,
            self.p99_ns,
            self.reads_per_op,
            self.rpcs_per_op,
            self.abort_rate(),
            self.nic_hit_rate,
        )
    }
}

impl std::fmt::Display for RunReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.row())
    }
}
