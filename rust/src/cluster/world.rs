//! The discrete-event cluster world.
//!
//! Every operation follows the full path the paper reasons about:
//!
//! ```text
//! client CPU (post) → PCIe doorbell → local NIC PU (QP state, penalty)
//!   → wire → remote NIC PU (QP + MPT + MTT charging, payload DMA)
//!   [→ remote CPU for RPCs: poll, handler, chain hops, response post]
//!   → wire → local NIC (CQE) → CQE DMA → client CPU (poll, coroutine)
//! ```
//!
//! The system under test changes exactly what the paper says changes:
//! Storm issues fine-grained one-sided reads with RPC fallback on RC;
//! eRPC sends everything over UD with software congestion control,
//! retransmission timers and receive-pool management; Lockfree_FaRM reads
//! whole hopscotch neighborhoods (8× larger transfers); Async_LITE funnels
//! every verb through a kernel with a global lock (but needs no NIC
//! MTT/MPT state — physical addressing).
//!
//! # Adaptive path selection and rack scale-out
//!
//! Storm's transport is no longer hard-wired per system. Each client node
//! carries a [`Transport`] controller that chooses the path *per
//! destination* on every post:
//!
//! * **RC** (the default): one-sided reads and write-imm RPCs on the
//!   sibling-pair mesh, striped by `conn_multiplier` and optionally
//!   multiplexed by `qp_share` (sibling-thread groups share one RC send
//!   queue per (pair, channel), paying a short serialization gate per
//!   post — `share_group_busy` — in exchange for an `s×` smaller NIC QP
//!   working set).
//! * **UD** (demoted destinations, or `TransportPolicy::StaticUd`): the
//!   request rides the thread's UD QP and pays the full datagram tax —
//!   software framing, [`RecvPool`] receive-buffer management at both
//!   ends, [`AppCc`] pacing + ack processing, and timeout retransmission
//!   ([`RetransmitState`], per-request entries in `CoroSim::pending_ud`).
//!   One-sided reads degrade into *read RPCs*: the responder's host CPU
//!   serves the view (`serve_read_request`), exactly the degradation the
//!   adaptive controller is trading NIC state pressure against.
//!
//! The controller watches the modeled NIC cache (cumulative hit/miss
//! counters plus a per-packet cold signal from `on_nic_tx`) in 50 µs
//! epochs and demotes/promotes destinations with hysteresis and
//! exponential per-destination cooldown (see [`crate::transport::adaptive`]).
//!
//! `SimConfig::fanout_nodes` scales the cluster out: the first
//! `cfg.nodes` machines run client threads while all `fanout_nodes`
//! machines store data and serve reads/RPCs, so a client NIC's QP working
//! set grows to hundreds of destinations × threads × `conn_multiplier`
//! without simulating hundreds of full client machines.
//!
//! The world is deterministic: one `Pcg64` stream per thread, FIFO event
//! ties, no host-time dependence.

use std::collections::VecDeque;
use std::time::Instant;

use crate::dataplane::onetwo::{DsCallbacks, LkAction, LkInput, LookupSm, ReadView};
use crate::dataplane::rpc::{request_wire_bytes, response_wire_bytes};
use crate::dataplane::tx::{TxEngine, TxInput, TxItem, TxOp, TxPost, TxStep};
use crate::ds::api::{LookupHint, LookupOutcome, ObjectId, RpcOp, RpcRequest, RpcResponse, RpcResult};
use crate::ds::btree::{BTreeConfig, BTreeRouteResolver, LEAF_BYTES};
use crate::ds::catalog::{Backend, Catalog, CatalogConfig, ObjectConfig, ObjectKind};
use crate::ds::hopscotch::HopscotchTable;
use crate::ds::mica::{owner_of, ItemView, MicaClient, MicaConfig};
use crate::fabric::FabricParams;
use crate::mem::{MrKey, RegionMode, RemoteAddr};
use crate::nic::{Nic, NicCache, NicOp, NicSide};
use crate::sim::{EventQueue, Histogram, MeterWindow, Nanos, Pcg64, RateMeter};
use crate::transport::adaptive::{PathChoice, Transport, TransportPolicy};
use crate::transport::cc::{AppCc, CcParams};
use crate::transport::topology::{Channel, ConnId, Topology};
use crate::transport::ud::{RecvPool, RetransmitDecision, RetransmitState};
use crate::workload::smallbank::{SmallBankPopulation, SmallBankWorkload};
use crate::workload::tatp::{TatpPopulation, TatpWorkload};
use crate::workload::KvWorkload;

use super::config::{SimConfig, StormMode, SystemKind, WorkloadKind};
use super::report::RunReport;

/// Extra NIC TX work factor for UD sends (software-framed datagrams).
const UD_TX_EXTRA_FACTOR: f64 = 0.4;
/// Capacity cost of the software congestion controller per paced packet,
/// as a multiple of the NIC PU service time (calibrated to the paper's
/// eRPC vs eRPC-noCC gap of ~1.53x at 16 nodes).
const CC_NIC_HOLD_FACTOR: f64 = 3.0;
/// Wire overhead bytes for a read request (headers only).
const READ_REQ_BYTES: u32 = 40;
/// Wire overhead added to a read response.
const READ_RESP_HDR: u32 = 30;
/// Backoff before retrying an aborted transaction.
const ABORT_BACKOFF: Nanos = 2_000;
/// CPU cost of a local (same-node) data-structure access.
const LOCAL_ACCESS_NS: Nanos = 150;
/// Posted-but-incomplete actions a coroutine keeps in flight when driving
/// the batched transaction engine on RC transports (the paper's intra-tx
/// parallelism: execute lookups and lock-reads together, validation reads
/// as one doorbell group, commit volleys). UD (eRPC) and synchronous LITE
/// drive a window of 1: their per-coroutine retransmit/sequence tracking
/// assumes a single outstanding request.
const INTRA_TX_WINDOW: usize = 16;
/// UD retransmission attempts before the timer gives up and re-arms fresh
/// (effectively unreachable inside a simulation horizon: 16 doublings of a
/// 300 µs RTO outlast any configured window; the cap exists so
/// [`RetransmitState`]'s give-up path is exercised rather than dead).
const UD_MAX_RETRIES: u32 = 16;

/// How a one-sided read should be served at the responder.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ReadKind {
    Bucket,
    ItemHeader,
    PerfectItem,
    Neighborhood,
}

#[derive(Clone, Debug)]
enum PktKind {
    ReadReq { obj: u8, key: u64, addr: RemoteAddr, len: u32, rk: ReadKind },
    ReadResp { view: ReadView },
    RpcReq { req: RpcRequest },
    RpcResp { resp: RpcResponse },
}

#[derive(Clone, Debug)]
struct Pkt {
    from: u16,
    to: u16,
    thread: u16,
    coro: u16,
    conn: ConnId,
    size: u32,
    seq: u16,
    /// Batched-engine action tag, echoed on the response so the coroutine
    /// can feed out-of-order completions back (0 for plain lookups).
    tag: u32,
    ud: bool,
    kind: PktKind,
}

enum Ev {
    /// Outbound processing at `at`'s NIC, then the wire.
    NicTx { at: u16, pkt: Pkt },
    /// Inbound processing at `pkt.to`'s NIC.
    NicRx { pkt: Pkt },
    /// Host-side delivery (CQE) at `pkt.to`.
    Deliver { pkt: Pkt },
    /// Kick a coroutine to start its next operation.
    CoroStart { node: u16, thread: u16, coro: u16 },
    /// UD retransmission timer.
    Retrans { node: u16, thread: u16, coro: u16, seq: u16 },
}

// ---------------------------------------------------------------------------
// Resolver: the client-side data-structure callbacks per system.

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum RMode {
    OneTwo,
    RpcOnly,
    Perfect,
    Farm,
}

struct FarmGeo {
    mask: u64,
    item_size: u32,
    h: u32,
    region_of: Vec<MrKey>,
}

/// One catalog object's client-side resolver in the simulator,
/// kind-dispatched (the simulated TATP variant with a B-link-backed
/// CALL_FORWARDING table mixes both kinds in one transaction).
enum SimObj {
    Mica(MicaClient),
    BTree(BTreeRouteResolver),
}

struct Resolver {
    mode: RMode,
    objs: Vec<SimObj>,
    farm: Option<FarmGeo>,
    nodes: u32,
    /// Copies per row; with `> 1` the engine's commit volley also ships
    /// backup applies to the chain's tail (see [`SimConfig::replication`]).
    replication: u32,
    /// Per-object placement override (PR 3 follow-up): CALL_FORWARDING
    /// range-partitioned by subscriber — `node = (key / span) % nodes`
    /// for object 3 when set, mirroring
    /// [`crate::ds::catalog::PlacementPolicy::Range`].
    cf_range_span: Option<u64>,
}

impl Resolver {
    fn dummy() -> Self {
        Resolver {
            mode: RMode::RpcOnly,
            objs: Vec::new(),
            farm: None,
            nodes: 1,
            replication: 1,
            cf_range_span: None,
        }
    }

    /// The object's MICA client (modes that predate the heterogeneous
    /// catalog — Perfect/Farm KV — only ever see MICA objects).
    fn mica(&mut self, obj: ObjectId) -> &mut MicaClient {
        match &mut self.objs[obj.0 as usize] {
            SimObj::Mica(c) => c,
            SimObj::BTree(_) => panic!("object {obj:?} is a B-link tree, not MICA"),
        }
    }
}

impl DsCallbacks for Resolver {
    fn lookup_start(&mut self, obj: ObjectId, key: u64) -> Option<LookupHint> {
        // The policy owner (range-partitioned objects diverge from the
        // hash owner; bucket/leaf offsets are node-independent, so only
        // the hint's node needs overriding).
        let own = self.owner(obj, key);
        match self.mode {
            RMode::RpcOnly => None,
            RMode::OneTwo => match &mut self.objs[obj.0 as usize] {
                SimObj::Mica(c) => {
                    let mut hint = c.lookup_start(key);
                    hint.node = own;
                    Some(hint)
                }
                // Cached-route traversal; cold routes decline and the
                // lookup's RPC re-traversal warms them.
                SimObj::BTree(b) => b.start(own, key),
            },
            RMode::Perfect => {
                let mut hint = self.mica(obj).lookup_start(key);
                // Fully warmed address cache: read exactly one item.
                hint.len = 128;
                hint.node = own;
                Some(hint)
            }
            RMode::Farm => {
                let g = self.farm.as_ref().expect("farm geometry");
                let node = own;
                let home = crate::ds::mica::fnv1a64(key) & g.mask;
                Some(LookupHint {
                    node,
                    addr: RemoteAddr {
                        region: g.region_of[node as usize],
                        offset: home * g.item_size as u64,
                    },
                    len: g.h * g.item_size,
                })
            }
        }
    }

    fn lookup_end_read(&mut self, obj: ObjectId, key: u64, view: &ReadView) -> LookupOutcome {
        let own = self.owner(obj, key);
        match (self.mode, view) {
            (RMode::Perfect, ReadView::Item(Some(v))) if v.key == key => {
                let addr = self.mica(obj).lookup_start(key).addr;
                LookupOutcome::Hit { version: v.version, addr, locked: v.locked }
            }
            (RMode::Perfect, ReadView::Item(_)) => LookupOutcome::Absent,
            (RMode::Farm, ReadView::Neighborhood(nv)) => {
                let g = self.farm.as_ref().unwrap();
                match HopscotchTable::find_in_view(nv, key) {
                    Some(version) => {
                        let node = own;
                        let home = crate::ds::mica::fnv1a64(key) & g.mask;
                        LookupOutcome::Hit {
                            version,
                            addr: RemoteAddr {
                                region: g.region_of[node as usize],
                                offset: home * g.item_size as u64,
                            },
                            locked: false,
                        }
                    }
                    // Hopscotch invariant: absence in the neighborhood is
                    // proof of absence.
                    None => LookupOutcome::Absent,
                }
            }
            (_, ReadView::Bucket(b)) => self.mica(obj).lookup_end_bucket(key, b),
            (_, ReadView::Item(i)) => self.mica(obj).lookup_end_item(key, *i),
            (_, ReadView::Leaf(leaf)) => match &mut self.objs[obj.0 as usize] {
                SimObj::BTree(b) => b.end_read(own, key, leaf.as_ref()),
                SimObj::Mica(_) => LookupOutcome::NeedRpc,
            },
            // Coarse-read views outside their mode: let the owner
            // resolve. (Leaf headers are validation reads; the engine —
            // not the lookup machine — consumes them.)
            (_, ReadView::Neighborhood(_)) | (_, ReadView::LeafHeader(_)) => {
                LookupOutcome::NeedRpc
            }
        }
    }

    fn lookup_end_rpc(&mut self, obj: ObjectId, key: u64, node: u32, resp: &RpcResponse) {
        match self.objs.get_mut(obj.0 as usize) {
            Some(SimObj::Mica(c)) => {
                if let RpcResult::Value { addr, .. } = &resp.result {
                    c.record_rpc_addr(key, node, *addr);
                }
            }
            Some(SimObj::BTree(b)) => b.end_rpc(node, resp),
            None => {}
        }
    }

    fn owner(&self, obj: ObjectId, key: u64) -> u32 {
        match self.cf_range_span {
            Some(span) if obj == crate::workload::tatp::CALL_FORWARDING => {
                ((key / span.max(1)) % self.nodes as u64) as u32
            }
            _ => owner_of(key, self.nodes),
        }
    }

    fn replicas(&self, obj: ObjectId, key: u64) -> Vec<u32> {
        let primary = self.owner(obj, key);
        (0..self.replication).map(|i| (primary + i) % self.nodes).collect()
    }

    fn backend_kind(&self, obj: ObjectId) -> ObjectKind {
        match self.objs.get(obj.0 as usize) {
            Some(SimObj::BTree(_)) => ObjectKind::BTree,
            _ => ObjectKind::Mica,
        }
    }
}

// ---------------------------------------------------------------------------
// Per-node state.

/// One simulated node's storage: the shared multi-object [`Catalog`]
/// (the same dispatcher the reference and live drivers serve RPCs with)
/// plus the hopscotch table the FaRM baseline reads.
struct Store {
    cat: Catalog,
    hop: Option<HopscotchTable>,
}

impl Store {
    fn serve_rpc(&mut self, req: &RpcRequest) -> RpcResponse {
        self.cat.serve_rpc(req)
    }
}

enum CoroSm {
    Idle,
    Kv(LookupSm),
    Tx(Box<TxEngine>),
}

/// One in-flight UD request of a coroutine: the packet (kept for
/// retransmission), its send time (CC RTT samples) and its timer. The
/// eRPC baseline keeps at most one (window of 1); the adaptive path's
/// demoted destinations ride inside the batched engine's window, so a
/// coroutine can have several outstanding at once.
struct PendingUd {
    seq: u16,
    sent_at: Nanos,
    retrans: RetransmitState,
    pkt: Pkt,
}

struct CoroSim {
    sm: CoroSm,
    op_start: Nanos,
    /// Monotonic per-coro sequence for UD request/dup matching.
    seq: u16,
    /// In-flight UD requests (request/dup matching + retransmission).
    pending_ud: Vec<PendingUd>,
    /// Transaction being executed, as its `(read set, write set)` item
    /// pair (retried verbatim on abort; TATP and SmallBank both feed it).
    pending_tx: Option<(Vec<TxItem>, Vec<TxItem>)>,
    /// Batched-engine actions emitted but not yet posted (driver window).
    posts: VecDeque<TxPost>,
    /// Posted-but-incomplete actions of this coroutine.
    outstanding: u32,
}

struct ThreadSim {
    busy_until: Nanos,
    resolver: Resolver,
    coros: Vec<CoroSim>,
    /// eRPC: per-destination congestion control.
    cc: Vec<AppCc>,
    rng: Pcg64,
    kv: Option<KvWorkload>,
    tatp: Option<TatpWorkload>,
    smallbank: Option<SmallBankWorkload>,
}

struct NodeSim {
    nic: Nic,
    threads: Vec<ThreadSim>,
    store: Store,
    recv_pool: RecvPool,
    /// Per-destination transport controller (consulted by client posts).
    transport: Transport,
    /// LITE: the kernel's global lock (a single serial server).
    kernel_busy: Nanos,
    /// FaRM ablation: shared-QP group locks.
    qp_group_busy: Vec<Nanos>,
    /// QP multiplexing: per-thread-group shared send-queue gates
    /// (`qp_share > 1`).
    share_group_busy: Vec<Nanos>,
    msg_region: MrKey,
    msg_region_len: u64,
}

#[derive(Default)]
struct Metrics {
    lat: Histogram,
    aborts: u64,
    commits: u64,
    reads: u64,
    rpcs: u64,
    ud_drops: u64,
    retrans: u64,
    found: u64,
    missing: u64,
}

// ---------------------------------------------------------------------------

/// The simulator.
pub struct World {
    /// Run configuration.
    pub cfg: SimConfig,
    topo: Topology,
    wire: FabricParams,
    q: EventQueue<Ev>,
    nodes: Vec<NodeSim>,
    meter: RateMeter,
    window: MeterWindow,
    metrics: Metrics,
    next_tx_id: u64,
    ud: bool,
    /// UD sends pay software congestion control (eRPC with CC, and every
    /// Storm run whose transport can demote to UD — the degradation price
    /// the adaptive controller weighs).
    ud_cc: bool,
    label: String,
}

impl World {
    /// Build a world from a configuration (loads all tables).
    pub fn new(cfg: SimConfig) -> Self {
        assert!(
            cfg.fanout_nodes == 0 || cfg.fanout_nodes >= cfg.nodes,
            "fanout_nodes must be 0 (off) or >= nodes"
        );
        assert!(cfg.qp_share >= 1, "qp_share is a divisor, not a toggle");
        assert!(
            cfg.transport == TransportPolicy::StaticRc
                || matches!(cfg.system, SystemKind::Storm(_)),
            "transport policies apply to Storm; the baselines keep their wired transports"
        );
        let total_nodes = cfg.total_nodes();
        let topo = Topology {
            nodes: total_nodes,
            threads: cfg.threads,
            conn_multiplier: cfg.conn_multiplier,
            qp_share: cfg.qp_share,
        };
        let wire = cfg.fabric.params();
        let mode = match cfg.system {
            SystemKind::Storm(StormMode::RpcOnly) | SystemKind::Erpc { .. } | SystemKind::Lite { .. } => {
                RMode::RpcOnly
            }
            SystemKind::Storm(StormMode::OneTwoSided) => RMode::OneTwo,
            SystemKind::Storm(StormMode::Perfect) => RMode::Perfect,
            SystemKind::Farm { .. } => RMode::Farm,
        };
        let ud = matches!(cfg.system, SystemKind::Erpc { .. });
        let ud_cc = matches!(cfg.system, SystemKind::Erpc { congestion_control: true })
            || (matches!(cfg.system, SystemKind::Storm(_))
                && cfg.transport != TransportPolicy::StaticRc);

        let region_mode = if cfg.physseg {
            RegionMode::PhysicalSegment
        } else {
            RegionMode::Virtual(cfg.page_size)
        };

        // --- table geometry ---------------------------------------------
        let mut table_cfgs: Vec<ObjectConfig> = match cfg.workload {
            WorkloadKind::KvLookups => vec![ObjectConfig::Mica(MicaConfig {
                buckets: cfg.buckets_per_node(cfg.keys_per_node),
                width: cfg.bucket_width,
                value_len: cfg.value_len,
                store_values: false,
            })],
            WorkloadKind::Tatp { subscribers_per_node } => {
                // Approximate per-node row counts per subscriber across
                // SUB/AI/SF/CF — the same ratios the live catalog is
                // sized with (`tatp::live_catalog`).
                let s = subscribers_per_node;
                crate::workload::tatp::ROWS_PER_SUBSCRIBER
                    .iter()
                    .map(|rows| {
                        ObjectConfig::Mica(MicaConfig {
                            buckets: cfg.buckets_per_node((s as f64 * rows).ceil() as u64),
                            width: cfg.bucket_width,
                            value_len: cfg.value_len,
                            store_values: false,
                        })
                    })
                    .collect()
            }
            WorkloadKind::SmallBank { accounts_per_node } => {
                // One row per customer in each of ACCOUNTS/SAVINGS/CHECKING.
                (0..3)
                    .map(|_| {
                        ObjectConfig::Mica(MicaConfig {
                            buckets: cfg.buckets_per_node(accounts_per_node),
                            width: cfg.bucket_width,
                            value_len: cfg.value_len,
                            store_values: false,
                        })
                    })
                    .collect()
            }
        };
        if cfg.tatp_cf_btree {
            // Heterogeneous TATP (PR 5): CALL_FORWARDING lives in a
            // B-link tree, so GetNewDestination validates leaf headers
            // and Insert/DeleteCallForwarding write through the tree —
            // leaf-granularity OCC on the simulated path. Sized with
            // ample split headroom (leaves hold up to 16 entries).
            let WorkloadKind::Tatp { subscribers_per_node } = cfg.workload else {
                panic!("tatp_cf_btree requires the TATP workload");
            };
            let cf_rows = (subscribers_per_node as f64
                * crate::workload::tatp::ROWS_PER_SUBSCRIBER[3])
                .ceil() as u64;
            let max_leaves = (cf_rows / 2).max(64);
            table_cfgs[3] = ObjectConfig::BTree(BTreeConfig { max_leaves });
        }
        let repl = cfg.replication.clamp(1, total_nodes);
        let cat_cfg = CatalogConfig::heterogeneous(table_cfgs.clone()).with_replication(repl);
        // Range-partitioned CALL_FORWARDING (PR 3 follow-up): 12 keys per
        // subscriber (the cf_key encoding), `subscribers_per_node` per
        // node — contiguous subscriber blocks walk the ring.
        let cf_span = if cfg.tatp_cf_range {
            let WorkloadKind::Tatp { subscribers_per_node } = cfg.workload else {
                panic!("tatp_cf_range requires the TATP workload");
            };
            Some(12 * subscribers_per_node)
        } else {
            None
        };

        // --- nodes: stores, NICs ----------------------------------------
        let mut nodes: Vec<NodeSim> = Vec::with_capacity(total_nodes as usize);
        for n in 0..total_nodes {
            // The node's storage catalog: the same multi-object dispatcher
            // the reference and live drivers use (one RPC-semantics
            // implementation for all three), with a simulator-sized chain
            // budget. The hopscotch table and the message rings register
            // into the catalog's region table afterwards, so NIC MTT/MPT
            // accounting still sees every region.
            let mut cat = Catalog::with_chunks(&cat_cfg, region_mode, 256);
            let hop = if mode == RMode::Farm {
                let buckets = (cfg.keys_per_node as f64 / 0.6).ceil() as u64;
                Some(HopscotchTable::new(
                    buckets.max(16).next_power_of_two(),
                    8,
                    128,
                    &mut cat.regions,
                    region_mode,
                ))
            } else {
                None
            };
            // Message rings: per-connection receive buffers (what Fig. 7's
            // emulation multiplies alongside connections).
            let msg_len = (topo.rc_conns_per_machine() * 8192).max(1 << 20);
            let msg_region = cat.regions.register(msg_len, region_mode);
            let mut nic = Nic::with_host_threads(cfg.nic.params(), cfg.threads);
            if matches!(cfg.system, SystemKind::Lite { .. }) {
                // LITE: kernel-managed physical addressing — the NIC holds
                // no MTT/MPT/QP-context working set worth caching.
                nic.bypass_state_cache = true;
            }
            if let Some(bytes) = cfg.nic_cache_override {
                // Deterministic degradation tests shrink the SRAM state
                // cache to force QP thrashing at modest cluster sizes.
                nic.cache = NicCache::new(bytes);
            }
            let _ = n;
            nodes.push(NodeSim {
                nic,
                threads: Vec::new(),
                store: Store { cat, hop },
                recv_pool: RecvPool::new(cfg.host.recv_pool_capacity),
                transport: Transport::new(cfg.transport, total_nodes),
                kernel_busy: 0,
                qp_group_busy: vec![0; (cfg.threads / cfg.host.farm_qp_group.max(1) + 1) as usize],
                share_group_busy: vec![0; (cfg.threads / cfg.qp_share.max(1) + 1) as usize],
                msg_region,
                msg_region_len: msg_len,
            });
        }

        // --- load data ----------------------------------------------------
        // Each row lands on its whole replica chain (primary + the next
        // `repl - 1` nodes); the FaRM hopscotch baseline stays
        // unreplicated — it predates the replicated catalog.
        let nnodes = total_nodes;
        // Per-(object, key) primary owner, honoring the range-partitioned
        // CALL_FORWARDING override so loaded rows land where the resolver
        // will route for them (mirrors `Resolver::owner`).
        let owner_for = move |obj: ObjectId, key: u64| -> u32 {
            match cf_span {
                Some(span) if obj == crate::workload::tatp::CALL_FORWARDING => {
                    ((key / span.max(1)) % nnodes as u64) as u32
                }
                _ => owner_of(key, nnodes),
            }
        };
        let chain_of = move |obj: ObjectId, key: u64| {
            (0..repl).map(move |i| (owner_for(obj, key) + i) % nnodes)
        };
        match cfg.workload {
            WorkloadKind::KvLookups => {
                for key in 1..=cfg.total_keys() {
                    if nodes[0].store.hop.is_some() {
                        let owner = owner_of(key, total_nodes) as usize;
                        nodes[owner].store.hop.as_mut().expect("farm store").insert(key, None);
                    } else {
                        for nd in chain_of(ObjectId(0), key) {
                            nodes[nd as usize].store.cat.insert(ObjectId(0), key, None);
                        }
                    }
                }
            }
            WorkloadKind::Tatp { subscribers_per_node } => {
                let pop = TatpPopulation::new(subscribers_per_node * total_nodes as u64);
                for (obj, key) in pop.rows(cfg.seed) {
                    for nd in chain_of(obj, key) {
                        nodes[nd as usize].store.cat.insert(obj, key, None);
                    }
                }
            }
            WorkloadKind::SmallBank { accounts_per_node } => {
                let pop = SmallBankPopulation::new(accounts_per_node * total_nodes as u64);
                for (obj, key) in pop.rows() {
                    for nd in chain_of(obj, key) {
                        nodes[nd as usize].store.cat.insert(obj, key, None);
                    }
                }
            }
        }

        // --- client threads ------------------------------------------------
        let region_of: Vec<Vec<MrKey>> = (0..table_cfgs.len())
            .map(|o| {
                nodes
                    .iter()
                    .map(|nd| match nd.store.cat.backend(ObjectId(o as u32)) {
                        Backend::Mica(t) => t.bucket_region,
                        Backend::BTree(t) => t.region,
                        other => panic!("unexpected {} backend in the simulator", other.kind_name()),
                    })
                    .collect()
            })
            .collect();
        let farm_regions: Vec<MrKey> = nodes
            .iter()
            .map(|nd| nd.store.hop.as_ref().map(|h| h.region).unwrap_or(MrKey(0)))
            .collect();
        let farm_mask = nodes[0]
            .store
            .hop
            .as_ref()
            .map(|h| (h.len(), h.neighborhood()))
            .map(|_| {
                let b = (cfg.keys_per_node as f64 / 0.6).ceil() as u64;
                b.max(16).next_power_of_two() - 1
            });

        // Every node gets threads — fan-out server nodes serve RPCs and
        // UD read requests on their sibling threads and pace responses
        // through per-destination CC state; only the first `cfg.nodes`
        // machines get coroutines scheduled (clients).
        for n in 0..total_nodes {
            for t in 0..cfg.threads {
                let objs: Vec<SimObj> = table_cfgs
                    .iter()
                    .enumerate()
                    .map(|(o, oc)| match oc {
                        ObjectConfig::Mica(tc) => SimObj::Mica(MicaClient::new(
                            ObjectId(o as u32),
                            tc,
                            total_nodes,
                            region_of[o].clone(),
                        )),
                        ObjectConfig::BTree(_) => {
                            SimObj::BTree(BTreeRouteResolver::new(total_nodes, LEAF_BYTES))
                        }
                        ObjectConfig::Hopscotch(_) | ObjectConfig::Queue(_) => {
                            panic!("the simulator's catalogs host MICA/BTree objects")
                        }
                    })
                    .collect();
                let farm = farm_mask.map(|mask| FarmGeo {
                    mask,
                    item_size: 128,
                    h: 8,
                    region_of: farm_regions.clone(),
                });
                let resolver = Resolver {
                    mode,
                    objs,
                    farm,
                    nodes: total_nodes,
                    replication: repl,
                    cf_range_span: cf_span,
                };
                let coros = (0..cfg.coros)
                    .map(|_| CoroSim {
                        sm: CoroSm::Idle,
                        op_start: 0,
                        seq: 0,
                        pending_ud: Vec::new(),
                        pending_tx: None,
                        posts: VecDeque::new(),
                        outstanding: 0,
                    })
                    .collect();
                let cc = (0..total_nodes).map(|_| AppCc::new(CcParams::default())).collect();
                let kv = match cfg.workload {
                    WorkloadKind::KvLookups => {
                        Some(KvWorkload::uniform(cfg.total_keys(), total_nodes))
                    }
                    _ => None,
                };
                let tatp = match cfg.workload {
                    WorkloadKind::Tatp { subscribers_per_node } => {
                        Some(TatpWorkload::new(subscribers_per_node * total_nodes as u64))
                    }
                    _ => None,
                };
                let smallbank = match cfg.workload {
                    WorkloadKind::SmallBank { accounts_per_node } => {
                        Some(SmallBankWorkload::new(accounts_per_node * total_nodes as u64))
                    }
                    _ => None,
                };
                nodes[n as usize].threads.push(ThreadSim {
                    busy_until: 0,
                    resolver,
                    coros,
                    cc,
                    rng: Pcg64::new(cfg.seed, (n as u64) << 16 | t as u64),
                    kv,
                    tatp,
                    smallbank,
                });
            }
        }

        let window = MeterWindow::new(cfg.warmup, cfg.warmup + cfg.measure);
        let label = Self::label_for(&cfg);
        let mut world = World {
            topo,
            wire,
            q: EventQueue::new(),
            nodes,
            meter: RateMeter::new(window),
            window,
            metrics: Metrics::default(),
            next_tx_id: 1,
            ud,
            ud_cc,
            label,
            cfg,
        };
        world.schedule_initial();
        world
    }

    fn label_for(cfg: &SimConfig) -> String {
        match cfg.system {
            SystemKind::Storm(StormMode::RpcOnly) => "Storm(rpc)".into(),
            SystemKind::Storm(StormMode::OneTwoSided) => "Storm(oversub)".into(),
            SystemKind::Storm(StormMode::Perfect) => "Storm(perfect)".into(),
            SystemKind::Erpc { congestion_control: true } => "eRPC".into(),
            SystemKind::Erpc { congestion_control: false } => "eRPC(noCC)".into(),
            SystemKind::Farm { locked_qp_sharing: false } => "Lockfree_FaRM".into(),
            SystemKind::Farm { locked_qp_sharing: true } => "FaRM(locked)".into(),
            SystemKind::Lite { async_ops: true } => "Async_LITE".into(),
            SystemKind::Lite { async_ops: false } => "LITE".into(),
        }
    }

    fn schedule_initial(&mut self) {
        let coros = if matches!(self.cfg.system, SystemKind::Lite { async_ops: false }) {
            1
        } else {
            self.cfg.coros
        };
        let mut idx = 0u64;
        for n in 0..self.cfg.nodes {
            for t in 0..self.cfg.threads {
                for c in 0..coros {
                    // Stagger starts to avoid a synchronized thundering herd.
                    let at = (idx % 997) * 23;
                    self.q.push_at(at, Ev::CoroStart { node: n as u16, thread: t as u16, coro: c as u16 });
                    idx += 1;
                }
            }
        }
    }

    /// Run to completion; consumes the world.
    pub fn run(mut self) -> RunReport {
        let end = self.cfg.warmup + self.cfg.measure;
        let wall = Instant::now();
        let mut events: u64 = 0;
        while let Some(ev) = self.q.pop() {
            if ev.at >= end {
                break;
            }
            events += 1;
            self.handle(ev.event);
        }
        let sim_ns = self.q.now();
        let nic_hit: f64 = self.nodes.iter().map(|n| n.nic.cache.hit_rate()).sum::<f64>()
            / self.nodes.len() as f64;
        let nic_util: f64 =
            self.nodes.iter().map(|n| n.nic.utilization(sim_ns)).sum::<f64>() / self.nodes.len() as f64;
        let ops = self.meter.ops();
        let active_qps = self.nodes.iter().map(|n| n.nic.active_qps()).max().unwrap_or(0);
        let nic_evictions: u64 = self.nodes.iter().map(|n| n.nic.cache.evictions()).sum();
        let demotions: u64 = self.nodes.iter().map(|n| n.transport.demotions()).sum();
        let promotions: u64 = self.nodes.iter().map(|n| n.transport.promotions()).sum();
        let ud_destinations: u32 = self.nodes.iter().map(|n| n.transport.ud_destinations()).sum();
        RunReport {
            label: self.label.clone(),
            nodes: self.cfg.nodes,
            ops,
            per_machine_mops: self.meter.mops() / self.cfg.nodes as f64,
            mean_ns: self.metrics.lat.mean(),
            p50_ns: self.metrics.lat.p50(),
            p99_ns: self.metrics.lat.p99(),
            aborts: self.metrics.aborts,
            reads_per_op: self.metrics.reads as f64 / ops.max(1) as f64,
            rpcs_per_op: self.metrics.rpcs as f64 / ops.max(1) as f64,
            nic_hit_rate: nic_hit,
            nic_utilization: nic_util,
            ud_drops: self.metrics.ud_drops,
            retransmits: self.metrics.retrans,
            active_qps,
            nic_evictions,
            demotions,
            promotions,
            ud_destinations,
            events,
            wall_ns: wall.elapsed().as_nanos() as u64,
            sim_ns,
        }
    }

    // -- event dispatch ----------------------------------------------------

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::NicTx { at, pkt } => self.on_nic_tx(at, pkt),
            Ev::NicRx { pkt } => self.on_nic_rx(pkt),
            Ev::Deliver { pkt } => self.on_deliver(pkt),
            Ev::CoroStart { node, thread, coro } => self.start_op(node, thread, coro),
            Ev::Retrans { node, thread, coro, seq } => self.on_retrans(node, thread, coro, seq),
        }
    }

    fn on_nic_tx(&mut self, at: u16, pkt: Pkt) {
        let now = self.q.now();
        let psvc = self.nodes[at as usize].nic.params.pu_service_ns;
        let extra = if pkt.ud { UD_TX_EXTRA_FACTOR * psvc } else { 0.0 };
        let mut op = NicOp::requester(NicSide::ReqTx, pkt.conn.0, pkt.size);
        op.extra_ns = extra;
        if pkt.ud && matches!(self.cfg.system, SystemKind::Erpc { congestion_control: true }) {
            // Onloaded congestion control: the software rate limiter's
            // per-packet descriptor work costs NIC issue capacity (the
            // overhead the paper's eRPC(noCC) variant avoids). Storm's
            // demoted destinations pay CC on the CPU (pace + ack work)
            // but skip eRPC's full rate-limiter descriptor ring, so this
            // NIC-capacity tax stays eRPC-only.
            op.extra_hold_ns = CC_NIC_HOLD_FACTOR * psvc;
        }
        let (finish, cost) = self.nodes[at as usize].nic.process(now, &op);
        if pkt.from != pkt.to && matches!(pkt.kind, PktKind::ReadReq { .. } | PktKind::RpcReq { .. })
        {
            // Feed the adaptive controller: a request whose QP context
            // missed the state cache or bounced a hot send slot is a
            // "cold" sample against its destination; the cumulative
            // cache counters give the controller its epoch hit-rate.
            let cold = cost.conn_penalty > 1.0 || cost.misses > 0;
            let nd = &mut self.nodes[at as usize];
            let (hits, misses) = (nd.nic.cache.hits(), nd.nic.cache.misses());
            nd.transport.on_tx(now, pkt.to as u32, cold, hits, misses);
        }
        let arrive = finish + self.wire.one_way_ns(pkt.size);
        self.q.push_at(arrive, Ev::NicRx { pkt });
    }

    fn on_nic_rx(&mut self, pkt: Pkt) {
        let now = self.q.now();
        let to = pkt.to as usize;
        match &pkt.kind {
            // One-sided read served by the responder's NIC (RC only; a
            // demoted destination's read request is a datagram handled by
            // the catch-all arm and served by host CPU).
            PktKind::ReadReq { obj, key, addr, len, rk } if !pkt.ud => {
                // Memory-state touches for the access.
                let (mpt, mtt) = {
                    let regions = &self.nodes[to].store.cat.regions;
                    let mut it = regions.mtt_entries_for(addr.region, addr.offset, *len as u64);
                    let first = it.next();
                    let count = 1 + it.count() as u32;
                    (
                        Some(addr.region.0 as u64),
                        first.map(|f| (f, count)),
                    )
                };
                let op = NicOp {
                    side: NicSide::RespRead,
                    qp: pkt.conn.0,
                    len: *len,
                    mpt,
                    mtt,
                    extra_ns: 0.0,
                    extra_hold_ns: 0.0,
                };
                let (finish, _) = self.nodes[to].nic.process(now, &op);
                // Resolve the view at access time.
                let view = self.serve_read(to, *obj, *key, *addr, *len, *rk);
                let resp_size = len + READ_RESP_HDR;
                let resp = Pkt {
                    from: pkt.to,
                    to: pkt.from,
                    thread: pkt.thread,
                    coro: pkt.coro,
                    conn: pkt.conn,
                    size: resp_size,
                    seq: pkt.seq,
                    tag: pkt.tag,
                    ud: false,
                    kind: PktKind::ReadResp { view },
                };
                self.q.push_at(finish + self.wire.one_way_ns(resp_size), Ev::NicRx { pkt: resp });
            }
            PktKind::ReadResp { .. } if !pkt.ud => {
                let op = NicOp::requester(NicSide::ReqRxCqe, pkt.conn.0, pkt.size);
                let (finish, _) = self.nodes[to].nic.process(now, &op);
                self.q.push_at(finish + self.cfg.host.cqe_dma as Nanos, Ev::Deliver { pkt });
            }
            // RPCs on any transport, plus the UD read-request/response
            // datagrams of demoted destinations.
            _ => {
                if pkt.ud && !self.nodes[to].recv_pool.arrive() {
                    // No posted receive buffer: the datagram is lost; the
                    // sender's retransmission timer will recover.
                    self.metrics.ud_drops += 1;
                    return;
                }
                // send/recv (two-sided) consumes more NIC work per message
                // than write-with-imm: RQ descriptor fetch + scatter without
                // the pre-written ring buffer (paper §5.2's argument).
                let side = if pkt.ud || self.cfg.rpc_via_sendrecv {
                    NicSide::RespRecvUd
                } else {
                    NicSide::RespRecvRc
                };
                // Message ring touch: the landing buffer's translation.
                let (mpt, mtt) = {
                    let nd = &self.nodes[to];
                    let off = (pkt.conn.0.wrapping_mul(8192)) % nd.msg_region_len;
                    let mut it = nd.store.cat.regions.mtt_entries_for(nd.msg_region, off, 64);
                    (Some(nd.msg_region.0 as u64), it.next().map(|f| (f, 1)))
                };
                let op = NicOp { side, qp: pkt.conn.0, len: pkt.size, mpt, mtt, extra_ns: 0.0, extra_hold_ns: 0.0 };
                let (finish, _) = self.nodes[to].nic.process(now, &op);
                self.q.push_at(finish + self.cfg.host.cqe_dma as Nanos, Ev::Deliver { pkt });
            }
        }
    }

    fn serve_read(
        &mut self,
        node: usize,
        obj: u8,
        key: u64,
        addr: RemoteAddr,
        len: u32,
        rk: ReadKind,
    ) -> ReadView {
        let store = &self.nodes[node].store;
        // Kind dispatch precedes the MICA read-granularity split: a read
        // aimed at a B-link object is a leaf read (full image for
        // lookups, bare OCC header for validation), whatever its length
        // classified as.
        if rk != ReadKind::Neighborhood {
            if let Backend::BTree(tree) = store.cat.backend(ObjectId(obj as u32)) {
                return if len >= LEAF_BYTES {
                    ReadView::Leaf(tree.leaf_view(addr))
                } else {
                    ReadView::LeafHeader(tree.leaf_header(addr))
                };
            }
        }
        match rk {
            ReadKind::Neighborhood => {
                ReadView::Neighborhood(store.hop.as_ref().expect("farm store").neighborhood_view(key))
            }
            ReadKind::Bucket => {
                let table = store.cat.table(ObjectId(obj as u32));
                let bb = table.config().bucket_bytes() as u64;
                let bucket = addr.offset / bb;
                ReadView::Bucket(table.bucket_view(bucket))
            }
            ReadKind::ItemHeader => {
                let table = store.cat.table(ObjectId(obj as u32));
                ReadView::Item(table.item_view(addr))
            }
            ReadKind::PerfectItem => {
                // Oracle: what a read of the item's true location returns.
                let table = store.cat.table(ObjectId(obj as u32));
                let _ = len;
                match table.get(key).0 {
                    RpcResult::Value { version, .. } => {
                        ReadView::Item(Some(ItemView { key, version, locked: false }))
                    }
                    _ => ReadView::Item(None),
                }
            }
        }
    }

    fn on_deliver(&mut self, pkt: Pkt) {
        match pkt.kind {
            PktKind::RpcReq { .. } => self.serve_rpc_request(pkt),
            PktKind::RpcResp { .. } | PktKind::ReadResp { .. } => self.resume_coro(pkt),
            PktKind::ReadReq { .. } => {
                // Only a demoted destination's read reaches the host: the
                // datagram degrades the one-sided read into a read RPC the
                // owner's CPU serves.
                debug_assert!(pkt.ud, "RC read requests never reach the host");
                self.serve_read_request(pkt);
            }
        }
    }

    /// Owner-side service of a degraded (UD) read request: resolve the
    /// same view the NIC would have DMA'd, but on the sibling thread's
    /// CPU, paying the full datagram receive tax (poll + framing +
    /// receive-buffer repost + CC pacing on the response).
    fn serve_read_request(&mut self, pkt: Pkt) {
        let now = self.q.now();
        let node = pkt.to as usize;
        let h = self.cfg.host;
        let PktKind::ReadReq { obj, key, addr, len, rk } = pkt.kind else {
            unreachable!()
        };
        let view = self.serve_read(node, obj, key, addr, len, rk);
        let mut cost = (h.poll
            + h.handler_base
            + h.post_wqe
            + h.ud_frame_cpu
            + h.recv_repost_base
            + h.recv_repost_per_node * self.cfg.nodes) as Nanos;
        self.nodes[node].recv_pool.repost(1);
        if self.ud_cc {
            cost += CcParams::default().cpu_send_ns as Nanos;
        }
        let thread = pkt.thread as usize;
        let start = self.nodes[node].threads[thread].busy_until.max(now);
        let done = start + cost;
        self.nodes[node].threads[thread].busy_until = done;
        let resp_size = len + READ_RESP_HDR;
        let out = Pkt {
            from: pkt.to,
            to: pkt.from,
            thread: pkt.thread,
            coro: pkt.coro,
            conn: pkt.conn,
            size: resp_size,
            seq: pkt.seq,
            tag: pkt.tag,
            ud: true,
            kind: PktKind::ReadResp { view },
        };
        let mut depart = done + h.doorbell_pcie as Nanos;
        if self.ud_cc {
            depart += self.nodes[node].threads[thread].cc[pkt.from as usize].on_send(done, resp_size);
        }
        self.q.push_at(depart, Ev::NicTx { at: pkt.to, pkt: out });
    }

    /// Server-side RPC execution on the sibling thread.
    fn serve_rpc_request(&mut self, pkt: Pkt) {
        let now = self.q.now();
        let node = pkt.to as usize;
        let h = self.cfg.host;
        let req = match &pkt.kind {
            PktKind::RpcReq { req } => req.clone(),
            _ => unreachable!(),
        };
        // Execute against the store.
        let resp = self.nodes[node].store.serve_rpc(&req);
        let hops = resp.hops;
        // Host CPU: poll + handler (+ per-system extras).
        let mut cost = (h.poll + h.handler_base + hops * h.handler_per_hop + h.post_wqe) as Nanos;
        if pkt.ud {
            cost += (h.ud_frame_cpu
                + h.recv_repost_base
                + h.recv_repost_per_node * self.cfg.nodes) as Nanos;
            self.nodes[node].recv_pool.repost(1);
            if self.ud_cc {
                cost += CcParams::default().cpu_send_ns as Nanos;
            }
        } else if self.cfg.rpc_via_sendrecv {
            // Two-sided RC still burns CPU reposting RQ descriptors.
            cost += h.recv_repost_base as Nanos;
        }
        let lite = matches!(self.cfg.system, SystemKind::Lite { .. });
        let thread = pkt.thread as usize;
        let start = self.nodes[node].threads[thread].busy_until.max(now);
        let mut done = start + cost;
        if lite {
            // Kernel mediation on the server side: two syscalls plus locked
            // kernel work.
            done += 2 * h.lite_syscall as Nanos;
            done = self.lite_kernel(node, done, h.lite_kernel_work as Nanos);
        }
        self.nodes[node].threads[thread].busy_until = done;
        // Response goes back as a write-with-imm (or UD send).
        let value_len = match &resp.result {
            // A reply that actually carries bytes (a B-link leaf image
            // riding a read re-traversal) is charged its real size; the
            // metadata-only MICA store charges the configured value.
            RpcResult::Value { value: Some(v), .. } => v.len() as u32,
            RpcResult::Value { .. } if matches!(req.op, RpcOp::Read | RpcOp::LockRead) => {
                self.cfg.value_len
            }
            _ => 0,
        };
        let size = response_wire_bytes(value_len);
        let out = Pkt {
            from: pkt.to,
            to: pkt.from,
            thread: pkt.thread,
            coro: pkt.coro,
            conn: pkt.conn,
            size,
            seq: pkt.seq,
            tag: pkt.tag,
            ud: pkt.ud,
            kind: PktKind::RpcResp { resp },
        };
        let mut depart = done + h.doorbell_pcie as Nanos;
        if pkt.ud && self.ud_cc {
            let pace = self.nodes[node].threads[thread].cc[pkt.from as usize].on_send(done, size);
            depart += pace;
        }
        self.q.push_at(depart, Ev::NicTx { at: pkt.to, pkt: out });
    }

    /// Client-side completion: resume the blocked coroutine.
    fn resume_coro(&mut self, pkt: Pkt) {
        let now = self.q.now();
        let h = self.cfg.host;
        let (node, thread, coro) = (pkt.to as usize, pkt.thread as usize, pkt.coro as usize);
        // UD duplicate filtering + receive-buffer replenish + CC ack.
        if pkt.ud {
            // The response consumed a posted receive buffer; the client's
            // completion handler reposts it (same as the server side).
            self.nodes[node].recv_pool.repost(1);
            let t = &mut self.nodes[node].threads[thread];
            t.busy_until = t.busy_until.max(now) + h.recv_repost_base as Nanos;
            let c = &mut self.nodes[node].threads[thread].coros[coro];
            let Some(pos) = c.pending_ud.iter().position(|p| p.seq == pkt.seq) else {
                return; // stale duplicate after a retransmission
            };
            let entry = c.pending_ud.swap_remove(pos);
            let rtt = now.saturating_sub(entry.sent_at);
            if self.ud_cc {
                self.nodes[node].threads[thread].cc[pkt.from as usize].on_ack(rtt);
                let extra = CcParams::default().cpu_ack_ns as Nanos;
                let t = &mut self.nodes[node].threads[thread];
                t.busy_until = t.busy_until.max(now) + extra;
            }
        }
        let mut cost = (h.poll + h.coro_switch) as Nanos;
        if matches!(self.cfg.system, SystemKind::Lite { .. }) {
            cost += h.lite_syscall as Nanos;
        }
        let start = self.nodes[node].threads[thread].busy_until.max(now);
        let mut ready = start + cost;
        if matches!(self.cfg.system, SystemKind::Lite { .. }) {
            ready = self.lite_kernel(node, ready, h.lite_kernel_completion as Nanos);
        }
        self.nodes[node].threads[thread].busy_until = ready;

        let tag = pkt.tag;
        let input = match pkt.kind {
            PktKind::ReadResp { view } => CoroInput::Read(view),
            PktKind::RpcResp { resp } => CoroInput::Rpc(resp),
            _ => unreachable!(),
        };
        self.advance_coro(node, thread, coro, Some((tag, input)), ready);
    }

    /// LITE's global kernel lock: serialize `work` through it.
    fn lite_kernel(&mut self, node: usize, ready: Nanos, work: Nanos) -> Nanos {
        let start = self.nodes[node].kernel_busy.max(ready);
        let done = start + work;
        self.nodes[node].kernel_busy = done;
        done
    }

    // -- coroutine driving ---------------------------------------------------

    fn start_op(&mut self, node: u16, thread: u16, coro: u16) {
        let now = self.q.now();
        let (n, t, c) = (node as usize, thread as usize, coro as usize);
        // Charge a coroutine switch for scheduling the next op.
        let start = self.nodes[n].threads[t].busy_until.max(now);
        let ready = start + self.cfg.host.coro_switch as Nanos;
        self.nodes[n].threads[t].busy_until = ready;

        // Sample the next operation.
        let th = &mut self.nodes[n].threads[t];
        let sm = if let Some(kv) = &th.kv {
            let key = kv.next_key(node as u32, &mut th.rng);
            CoroSm::Kv(LookupSm::new(ObjectId(0), key))
        } else {
            // Transactional workloads: TATP or SmallBank item sets feed
            // the same batched engine.
            let (read_set, write_set) = if let Some(tatp) = &th.tatp {
                let tx = tatp.next_tx(&mut th.rng);
                (tx.read_set, tx.write_set)
            } else {
                let sb = th.smallbank.as_ref().expect("some workload must be configured");
                let tx = sb.next_tx(&mut th.rng);
                (tx.read_set, tx.write_set)
            };
            th.coros[c].pending_tx = Some((read_set.clone(), write_set.clone()));
            let id = self.next_tx_id;
            self.next_tx_id += 1;
            CoroSm::Tx(Box::new(TxEngine::begin(id, read_set, write_set)))
        };
        self.nodes[n].threads[t].coros[c].sm = sm;
        self.nodes[n].threads[t].coros[c].op_start = ready;
        self.advance_coro(n, t, c, None, ready);
    }

    /// Posted-action window for the batched transaction engine.
    fn tx_post_window(&self) -> usize {
        if self.ud || matches!(self.cfg.system, SystemKind::Lite { async_ops: false }) {
            1
        } else {
            INTRA_TX_WINDOW
        }
    }

    fn advance_coro(
        &mut self,
        n: usize,
        t: usize,
        c: usize,
        input: Option<(u32, CoroInput)>,
        ready: Nanos,
    ) {
        // Take the state machine and resolver out to appease the borrow
        // checker; both go back before any early return below.
        let mut sm = std::mem::replace(&mut self.nodes[n].threads[t].coros[c].sm, CoroSm::Idle);
        let mut resolver =
            std::mem::replace(&mut self.nodes[n].threads[t].resolver, Resolver::dummy());
        if input.is_some() && matches!(&sm, CoroSm::Tx(_)) {
            self.nodes[n].threads[t].coros[c].outstanding -= 1;
        }
        let next = match &mut sm {
            CoroSm::Kv(lk) => {
                let lk_input = input.map(|(_, i)| match i {
                    CoroInput::Read(v) => LkInput::Read(v),
                    CoroInput::Rpc(r) => LkInput::Rpc(r),
                });
                match lk.advance(&mut resolver, lk_input) {
                    LkAction::Read { obj, key, node, addr, len } => {
                        CoroNext::Act(CoroAction::Read { obj, key, dest: node, addr, len })
                    }
                    LkAction::Rpc { node, req } => {
                        CoroNext::Act(CoroAction::Rpc { dest: node, req })
                    }
                    LkAction::Done(res) => {
                        CoroNext::Act(CoroAction::KvDone { found: res.found })
                    }
                }
            }
            CoroSm::Tx(tx) => {
                // Batched contract: start once, then feed each tagged
                // completion; every step may emit a batch of independent
                // actions the post window drains.
                let step = match input {
                    None => tx.start(&mut resolver),
                    Some((tag, i)) => {
                        let tx_input = match i {
                            CoroInput::Read(v) => TxInput::Read(v),
                            CoroInput::Rpc(r) => TxInput::Rpc(r),
                        };
                        tx.complete(&mut resolver, tag, tx_input)
                    }
                };
                match step {
                    TxStep::Issue(posts) => CoroNext::TxIssue(posts),
                    TxStep::Done(outcome) => CoroNext::TxDone {
                        committed: matches!(
                            outcome,
                            crate::dataplane::tx::TxOutcome::Committed { .. }
                        ),
                    },
                }
            }
            CoroSm::Idle => unreachable!("idle coroutine advanced"),
        };
        self.nodes[n].threads[t].coros[c].sm = sm;
        self.nodes[n].threads[t].resolver = resolver;

        let in_window = self.window.contains(ready);
        match next {
            CoroNext::Act(CoroAction::Read { obj, key, dest, addr, len }) => {
                if in_window {
                    self.metrics.reads += 1;
                }
                self.post_read(n, t, c, 0, obj, key, dest, addr, len, ready, None);
            }
            CoroNext::Act(CoroAction::Rpc { dest, req }) => {
                if in_window {
                    self.metrics.rpcs += 1;
                }
                self.post_rpc(n, t, c, 0, dest, req, ready, None);
            }
            CoroNext::Act(CoroAction::KvDone { found }) => {
                if found {
                    self.metrics.found += 1;
                } else {
                    self.metrics.missing += 1;
                }
                self.finish_op(n, t, c, ready, true);
            }
            CoroNext::TxIssue(posts) => {
                self.nodes[n].threads[t].coros[c].posts.extend(posts);
                self.pump_tx_posts(n, t, c, ready);
            }
            CoroNext::TxDone { committed } => {
                debug_assert_eq!(self.nodes[n].threads[t].coros[c].outstanding, 0);
                debug_assert!(self.nodes[n].threads[t].coros[c].posts.is_empty());
                if committed {
                    self.metrics.commits += 1;
                    self.nodes[n].threads[t].coros[c].pending_tx = None;
                    self.finish_op(n, t, c, ready, true);
                } else {
                    if in_window {
                        self.metrics.aborts += 1;
                    }
                    self.retry_tx(n, t, c, ready);
                }
            }
        }
    }

    /// Post queued engine actions while the coroutine's window has room.
    ///
    /// Doorbell coalescing (ROADMAP follow-up): the actions of one pumped
    /// batch destined to the same `(node, path)` are written back-to-back
    /// as a WQE chain and ride a **single doorbell, rung after the
    /// group's last WQE write** — so every chained packet becomes
    /// NIC-visible together at ring time, exactly the way hardware posts
    /// a chain (a WQE written after an earlier ring would be invisible
    /// until the next one).
    fn pump_tx_posts(&mut self, n: usize, t: usize, c: usize, ready: Nanos) {
        let window = self.tx_post_window();
        let in_window = self.window.contains(ready);
        // Per (dest node, is_rpc_path) group: the chained packets and the
        // CPU time each WQE write finished at.
        let mut chains: Vec<((u32, bool), Vec<(Nanos, Pkt)>)> = Vec::new();
        fn chain_entry(
            chains: &mut Vec<((u32, bool), Vec<(Nanos, Pkt)>)>,
            key: (u32, bool),
        ) -> &mut Vec<(Nanos, Pkt)> {
            if let Some(i) = chains.iter().position(|(k, _)| *k == key) {
                return &mut chains[i].1;
            }
            chains.push((key, Vec::new()));
            &mut chains.last_mut().expect("just pushed").1
        }
        loop {
            let coro = &mut self.nodes[n].threads[t].coros[c];
            if coro.outstanding as usize >= window {
                break;
            }
            let Some(post) = coro.posts.pop_front() else { break };
            coro.outstanding += 1;
            match post.op {
                TxOp::Read { obj, key, node, addr, len } => {
                    if in_window {
                        self.metrics.reads += 1;
                    }
                    // Local accesses use no verbs and never chain.
                    let chain = if node as usize != n {
                        Some(chain_entry(&mut chains, (node, false)))
                    } else {
                        None
                    };
                    self.post_read(n, t, c, post.tag, obj, key, node, addr, len, ready, chain);
                }
                TxOp::Rpc { node, req } => {
                    if in_window {
                        self.metrics.rpcs += 1;
                    }
                    let chain = if node as usize != n {
                        Some(chain_entry(&mut chains, (node, true)))
                    } else {
                        None
                    };
                    self.post_rpc(n, t, c, post.tag, node, req, ready, chain);
                }
            }
        }
        // Ring each group's doorbell once, after its last WQE write; the
        // whole chain departs for the NIC together.
        let doorbell = self.cfg.host.doorbell_pcie as Nanos;
        for (_, members) in chains {
            let ring = members.iter().map(|&(wrote, _)| wrote).max().expect("chain non-empty")
                + doorbell;
            for (_, pkt) in members {
                self.q.push_at(ring, Ev::NicTx { at: n as u16, pkt });
            }
        }
    }

    fn finish_op(&mut self, n: usize, t: usize, c: usize, done: Nanos, count: bool) {
        if count {
            let started = self.nodes[n].threads[t].coros[c].op_start;
            if self.window.contains(done) {
                self.meter.record(done);
                self.metrics.lat.record(done.saturating_sub(started));
            }
        }
        self.nodes[n].threads[t].coros[c].sm = CoroSm::Idle;
        self.q.push_at(done, Ev::CoroStart { node: n as u16, thread: t as u16, coro: c as u16 });
    }

    fn retry_tx(&mut self, n: usize, t: usize, c: usize, ready: Nanos) {
        let (read_set, write_set) = self.nodes[n].threads[t].coros[c]
            .pending_tx
            .clone()
            .expect("aborted tx must be retryable");
        let id = self.next_tx_id;
        self.next_tx_id += 1;
        self.nodes[n].threads[t].coros[c].sm =
            CoroSm::Tx(Box::new(TxEngine::begin(id, read_set, write_set)));
        // Keep the original op_start: retries count toward the latency of
        // the logical transaction.
        let resume = ready + ABORT_BACKOFF;
        let (n16, t16, c16) = (n as u16, t as u16, c as u16);
        // Re-enter via a scheduled event so the backoff is honored.
        self.q.push_at(resume, Ev::Retrans { node: n16, thread: t16, coro: c16, seq: u16::MAX });
    }

    // -- posting ---------------------------------------------------------

    /// Post a one-sided read. With `chain`, the WQE joins a coalesced
    /// doorbell group: the packet is handed back (tagged with the time
    /// its WQE write finished) for the caller to launch when the group's
    /// single doorbell rings; without it, the post rings its own.
    #[allow(clippy::too_many_arguments)]
    fn post_read(
        &mut self,
        n: usize,
        t: usize,
        c: usize,
        tag: u32,
        obj: ObjectId,
        key: u64,
        dest: u32,
        addr: RemoteAddr,
        len: u32,
        ready: Nanos,
        chain: Option<&mut Vec<(Nanos, Pkt)>>,
    ) {
        let h = self.cfg.host;
        let rk = self.classify_read(len);
        if dest as usize == n {
            // Local access: no verbs, just a memory read (the hash-table
            // probe the owner would do).
            let start = self.nodes[n].threads[t].busy_until.max(ready);
            let done = start + LOCAL_ACCESS_NS;
            self.nodes[n].threads[t].busy_until = done;
            let view = self.serve_read(n, obj.0 as u8, key, addr, len, rk);
            let pkt = Pkt {
                from: n as u16,
                to: n as u16,
                thread: t as u16,
                coro: c as u16,
                conn: ConnId(0),
                size: 0,
                seq: 0,
                tag,
                ud: false,
                kind: PktKind::ReadResp { view },
            };
            self.q.push_at(done, Ev::Deliver { pkt });
            return;
        }
        if self.nodes[n].transport.choose(dest) == PathChoice::Ud {
            // Demoted destination: the one-sided read degrades into a
            // datagram read RPC served by the owner's CPU.
            self.post_read_ud(n, t, c, tag, obj, key, dest, addr, len, ready, chain);
            return;
        }
        let start = self.nodes[n].threads[t].busy_until.max(ready);
        let mut cpu_done = start + h.post_wqe as Nanos;
        self.nodes[n].threads[t].busy_until = cpu_done;
        cpu_done = self.apply_post_gates(n, t, cpu_done, true);
        let lane = (c as u32) % self.topo.conn_multiplier;
        let conn = self.topo.rc_conn(n as u32, dest, t as u32, Channel::ReadPath, lane);
        let pkt = Pkt {
            from: n as u16,
            to: dest as u16,
            thread: t as u16,
            coro: c as u16,
            conn,
            size: READ_REQ_BYTES.max(len / 16), // request carries no payload
            seq: 0,
            tag,
            ud: false,
            kind: PktKind::ReadReq { obj: obj.0 as u8, key, addr, len, rk },
        };
        // A chained WQE waits for the group's single doorbell (rung after
        // the batch's last write); an unchained post rings its own.
        match chain {
            Some(chain) => chain.push((cpu_done, pkt)),
            None => {
                self.q.push_at(cpu_done + h.doorbell_pcie as Nanos, Ev::NicTx { at: n as u16, pkt })
            }
        }
    }

    /// Post a degraded read: same request semantics, but carried as a UD
    /// datagram and served by the responder's host CPU. Pays the full
    /// datagram tax — software framing, CC pacing (`ud_cc`) and an
    /// in-flight entry with a retransmission timer.
    #[allow(clippy::too_many_arguments)]
    fn post_read_ud(
        &mut self,
        n: usize,
        t: usize,
        c: usize,
        tag: u32,
        obj: ObjectId,
        key: u64,
        dest: u32,
        addr: RemoteAddr,
        len: u32,
        ready: Nanos,
        chain: Option<&mut Vec<(Nanos, Pkt)>>,
    ) {
        let h = self.cfg.host;
        let rk = self.classify_read(len);
        let mut cost = (h.post_wqe + h.ud_frame_cpu) as Nanos;
        if self.ud_cc {
            cost += CcParams::default().cpu_send_ns as Nanos;
        }
        let start = self.nodes[n].threads[t].busy_until.max(ready);
        let mut cpu_done = start + cost;
        self.nodes[n].threads[t].busy_until = cpu_done;
        cpu_done = self.apply_post_gates(n, t, cpu_done, false);
        let size = READ_REQ_BYTES.max(len / 16);
        let mut pace = 0;
        if self.ud_cc {
            pace = self.nodes[n].threads[t].cc[dest as usize].on_send(cpu_done, size);
        }
        let seq = {
            let coro = &mut self.nodes[n].threads[t].coros[c];
            coro.seq = coro.seq.wrapping_add(1);
            coro.seq
        };
        let pkt = Pkt {
            from: n as u16,
            to: dest as u16,
            thread: t as u16,
            coro: c as u16,
            conn: self.topo.ud_qp(n as u32, t as u32),
            size,
            seq,
            tag,
            ud: true,
            kind: PktKind::ReadReq { obj: obj.0 as u8, key, addr, len, rk },
        };
        self.arm_ud(n, t, c, pkt.clone(), cpu_done + pace);
        match chain {
            Some(chain) => chain.push((cpu_done + pace, pkt)),
            None => self
                .q
                .push_at(cpu_done + pace + h.doorbell_pcie as Nanos, Ev::NicTx { at: n as u16, pkt }),
        }
    }

    /// Track an in-flight UD request: a dup-matching entry carrying the
    /// packet for retransmission, plus its armed timer event.
    fn arm_ud(&mut self, n: usize, t: usize, c: usize, pkt: Pkt, sent_at: Nanos) {
        let h = self.cfg.host;
        let seq = pkt.seq;
        self.nodes[n].threads[t].coros[c].pending_ud.push(PendingUd {
            seq,
            sent_at,
            retrans: RetransmitState::armed(sent_at, h.rto, UD_MAX_RETRIES),
            pkt,
        });
        self.q.push_at(
            sent_at + h.rto,
            Ev::Retrans { node: n as u16, thread: t as u16, coro: c as u16, seq },
        );
    }

    /// Post a write-based RPC (see [`World::post_read`] for the `chain`
    /// contract).
    #[allow(clippy::too_many_arguments)]
    fn post_rpc(
        &mut self,
        n: usize,
        t: usize,
        c: usize,
        tag: u32,
        dest: u32,
        req: RpcRequest,
        ready: Nanos,
        chain: Option<&mut Vec<(Nanos, Pkt)>>,
    ) {
        let h = self.cfg.host;
        if dest as usize == n {
            // Local "RPC": run the handler inline on this thread.
            let resp = self.nodes[n].store.serve_rpc(&req);
            let cost = (h.handler_base + resp.hops * h.handler_per_hop) as Nanos;
            let start = self.nodes[n].threads[t].busy_until.max(ready);
            let done = start + cost;
            self.nodes[n].threads[t].busy_until = done;
            let pkt = Pkt {
                from: n as u16,
                to: n as u16,
                thread: t as u16,
                coro: c as u16,
                conn: ConnId(0),
                size: 0,
                seq: 0,
                tag,
                ud: false,
                kind: PktKind::RpcResp { resp },
            };
            self.q.push_at(done, Ev::Deliver { pkt });
            return;
        }
        // eRPC/LITE wire everything over UD; Storm's controller can demote
        // individual destinations onto the datagram path.
        let ud = self.ud || self.nodes[n].transport.choose(dest) == PathChoice::Ud;
        // request_wire_bytes already includes the 16-byte RPC header.
        let mut size = request_wire_bytes(&req);
        if matches!(req.op, RpcOp::ReplicaUpsert) && req.value.is_none() {
            // The metadata-only simulator carries no value bytes, but a
            // backup apply ships the committed image on the wire — charge
            // the configured value size so replication's bandwidth tax is
            // modeled.
            size += self.cfg.value_len;
        }
        let mut cost = h.post_wqe as Nanos;
        if ud {
            cost += h.ud_frame_cpu as Nanos;
            if self.ud_cc {
                cost += CcParams::default().cpu_send_ns as Nanos;
            }
        }
        let start = self.nodes[n].threads[t].busy_until.max(ready);
        let mut cpu_done = start + cost;
        self.nodes[n].threads[t].busy_until = cpu_done;
        cpu_done = self.apply_post_gates(n, t, cpu_done, !ud);

        let mut pace = 0;
        if ud && self.ud_cc {
            pace = self.nodes[n].threads[t].cc[dest as usize].on_send(cpu_done, size);
        }
        let seq = {
            let coro = &mut self.nodes[n].threads[t].coros[c];
            coro.seq = coro.seq.wrapping_add(1);
            coro.seq
        };
        let conn = if ud {
            self.topo.ud_qp(n as u32, t as u32)
        } else {
            let lane = (c as u32) % self.topo.conn_multiplier;
            self.topo.rc_conn(n as u32, dest, t as u32, Channel::RpcPath, lane)
        };
        let pkt = Pkt {
            from: n as u16,
            to: dest as u16,
            thread: t as u16,
            coro: c as u16,
            conn,
            size,
            seq,
            tag,
            ud,
            kind: PktKind::RpcReq { req },
        };
        if ud {
            self.arm_ud(n, t, c, pkt.clone(), cpu_done + pace);
        }
        // A chained WQE waits for the group's single doorbell (rung after
        // the batch's last write); an unchained post rings its own.
        match chain {
            Some(chain) => chain.push((cpu_done + pace, pkt)),
            None => self
                .q
                .push_at(cpu_done + pace + h.doorbell_pcie as Nanos, Ev::NicTx { at: n as u16, pkt }),
        }
    }

    /// Per-system gates on the post path: LITE's kernel lock, FaRM's shared
    /// QP locks, and — on shared RC send queues (`qp_share > 1`, flagged by
    /// `shared_rc`) — the short per-group serialization of QP multiplexing.
    fn apply_post_gates(&mut self, n: usize, t: usize, cpu_done: Nanos, shared_rc: bool) -> Nanos {
        let h = self.cfg.host;
        match self.cfg.system {
            SystemKind::Lite { .. } => {
                let entered = cpu_done + h.lite_syscall as Nanos;
                self.lite_kernel(n, entered, h.lite_kernel_work as Nanos)
            }
            SystemKind::Farm { locked_qp_sharing: true } => {
                // Original FaRM: the QP-group lock is held across WQE
                // build + doorbell MMIO, serializing the group's posts.
                let g = (t as u32 / h.farm_qp_group.max(1)) as usize;
                let start = self.nodes[n].qp_group_busy[g].max(cpu_done);
                let done =
                    start + (h.farm_qp_lock + h.post_wqe + h.doorbell_pcie) as Nanos;
                self.nodes[n].qp_group_busy[g] = done;
                done
            }
            _ if shared_rc && self.cfg.qp_share > 1 => {
                // QP multiplexing: sibling threads sharing one RC send
                // queue serialize briefly per post (uncontended CAS +
                // doorbell-record update — far cheaper than FaRM's lock,
                // which spans the whole WQE build + MMIO).
                let g = (t as u32 / self.cfg.qp_share) as usize;
                let start = self.nodes[n].share_group_busy[g].max(cpu_done);
                let done = start + h.qp_share_lock as Nanos;
                self.nodes[n].share_group_busy[g] = done;
                done
            }
            _ => cpu_done,
        }
    }

    fn classify_read(&self, len: u32) -> ReadKind {
        match self.cfg.system {
            SystemKind::Farm { .. } => ReadKind::Neighborhood,
            SystemKind::Storm(StormMode::Perfect) => {
                if len == crate::ds::mica::ITEM_HEADER {
                    ReadKind::ItemHeader
                } else {
                    ReadKind::PerfectItem
                }
            }
            _ => {
                if len == crate::ds::mica::ITEM_HEADER {
                    ReadKind::ItemHeader
                } else {
                    ReadKind::Bucket
                }
            }
        }
    }

    fn on_retrans(&mut self, node: u16, thread: u16, coro: u16, seq: u16) {
        let now = self.q.now();
        let (n, t, c) = (node as usize, thread as usize, coro as usize);
        if seq == u16::MAX {
            // Abort-retry kick (reuses the timer event).
            self.advance_coro(n, t, c, None, now);
            return;
        }
        let h = self.cfg.host;
        let Some(pos) =
            self.nodes[n].threads[t].coros[c].pending_ud.iter().position(|p| p.seq == seq)
        else {
            return; // the response arrived before the timer fired
        };
        let entry = &mut self.nodes[n].threads[t].coros[c].pending_ud[pos];
        match entry.retrans.on_timeout(now) {
            RetransmitDecision::Retry => {
                entry.sent_at = now;
                let deadline = entry.retrans.deadline;
                let pkt = entry.pkt.clone();
                self.metrics.retrans += 1;
                self.q.push_at(deadline, Ev::Retrans { node, thread, coro, seq });
                self.q.push_at(now + h.doorbell_pcie as Nanos, Ev::NicTx { at: node, pkt });
            }
            RetransmitDecision::GiveUp => {
                // Effectively unreachable inside a simulation horizon (16
                // doublings of the RTO); re-arm fresh so a pathological
                // run still terminates instead of losing the coroutine.
                entry.retrans = RetransmitState::armed(now, h.rto, UD_MAX_RETRIES);
                entry.sent_at = now;
                let pkt = entry.pkt.clone();
                self.metrics.retrans += 1;
                self.q.push_at(now + h.rto, Ev::Retrans { node, thread, coro, seq });
                self.q.push_at(now + h.doorbell_pcie as Nanos, Ev::NicTx { at: node, pkt });
            }
        }
    }
}

enum CoroInput {
    Read(ReadView),
    Rpc(RpcResponse),
}

enum CoroAction {
    Read { obj: ObjectId, key: u64, dest: u32, addr: RemoteAddr, len: u32 },
    Rpc { dest: u32, req: RpcRequest },
    KvDone { found: bool },
}

/// What a coroutine advance decided: a single lookup action, a batch of
/// transaction-engine posts for the window pump, or a finished tx.
enum CoroNext {
    Act(CoroAction),
    TxIssue(Vec<TxPost>),
    TxDone { committed: bool },
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{MICRO, MILLI};

    fn quick_cfg(system: SystemKind, nodes: u32) -> SimConfig {
        let mut cfg = SimConfig::new(system, nodes);
        cfg.threads = 2;
        cfg.coros = 4;
        cfg.keys_per_node = 4_000;
        cfg.warmup = 100 * MICRO;
        cfg.measure = 1 * MILLI;
        cfg
    }

    #[test]
    fn event_size_budget() {
        // Events move through the binary heap; keep them lean.
        eprintln!(
            "Ev={}B Pkt={}B ReadView={}B",
            std::mem::size_of::<Ev>(),
            std::mem::size_of::<Pkt>(),
            std::mem::size_of::<ReadView>()
        );
        // Budget allows the 4-byte completion tag the batched engine needs.
        assert!(std::mem::size_of::<Ev>() <= 168);
    }

    #[test]
    fn storm_oversub_runs_and_reports() {
        let cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 4);
        let r = World::new(cfg).run();
        assert!(r.ops > 1_000, "ops {}", r.ops);
        assert!(r.per_machine_mops > 0.1, "mops {}", r.per_machine_mops);
        assert!(r.mean_ns > 1_000.0, "latency {}", r.mean_ns);
        // Oversubscribed table: mostly single reads, few RPC fallbacks.
        assert!(r.reads_per_op >= 0.95, "reads/op {}", r.reads_per_op);
        assert!(r.rpcs_per_op < 0.5, "rpcs/op {}", r.rpcs_per_op);
    }

    #[test]
    fn storm_rpc_only_uses_no_reads() {
        let cfg = quick_cfg(SystemKind::Storm(StormMode::RpcOnly), 4);
        let r = World::new(cfg).run();
        assert!(r.ops > 1_000);
        assert_eq!(r.reads_per_op, 0.0);
        assert!(r.rpcs_per_op >= 0.99);
    }

    #[test]
    fn storm_perfect_never_rpcs() {
        let cfg = quick_cfg(SystemKind::Storm(StormMode::Perfect), 4);
        let r = World::new(cfg).run();
        assert!(r.ops > 1_000);
        assert_eq!(r.rpcs_per_op, 0.0, "perfect mode must not RPC");
        assert!((r.reads_per_op - 1.0).abs() < 0.01);
    }

    #[test]
    fn perfect_beats_rpc_only() {
        let perfect = World::new(quick_cfg(SystemKind::Storm(StormMode::Perfect), 4)).run();
        let rpc = World::new(quick_cfg(SystemKind::Storm(StormMode::RpcOnly), 4)).run();
        assert!(
            perfect.per_machine_mops > rpc.per_machine_mops * 1.3,
            "perfect {} vs rpc {}",
            perfect.per_machine_mops,
            rpc.per_machine_mops
        );
    }

    #[test]
    fn erpc_runs_and_is_slower_than_storm() {
        let storm = World::new(quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 4)).run();
        let erpc = World::new(quick_cfg(SystemKind::Erpc { congestion_control: true }, 4)).run();
        assert!(erpc.ops > 500);
        assert!(
            storm.per_machine_mops > erpc.per_machine_mops,
            "storm {} vs erpc {}",
            storm.per_machine_mops,
            erpc.per_machine_mops
        );
    }

    #[test]
    fn erpc_no_cc_beats_cc() {
        let cc = World::new(quick_cfg(SystemKind::Erpc { congestion_control: true }, 4)).run();
        let nocc = World::new(quick_cfg(SystemKind::Erpc { congestion_control: false }, 4)).run();
        assert!(
            nocc.per_machine_mops > cc.per_machine_mops,
            "noCC {} vs CC {}",
            nocc.per_machine_mops,
            cc.per_machine_mops
        );
    }

    #[test]
    fn farm_reads_whole_neighborhoods() {
        let r = World::new(quick_cfg(SystemKind::Farm { locked_qp_sharing: false }, 4)).run();
        assert!(r.ops > 1_000);
        assert!((r.reads_per_op - 1.0).abs() < 0.05, "farm reads/op {}", r.reads_per_op);
        assert_eq!(r.rpcs_per_op, 0.0);
    }

    #[test]
    fn lite_is_much_slower() {
        let storm = World::new(quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 4)).run();
        let lite = World::new(quick_cfg(SystemKind::Lite { async_ops: true }, 4)).run();
        assert!(lite.ops > 100);
        assert!(
            storm.per_machine_mops > lite.per_machine_mops * 4.0,
            "storm {} vs lite {}",
            storm.per_machine_mops,
            lite.per_machine_mops
        );
    }

    #[test]
    fn async_lite_beats_sync_lite_single_thread() {
        // Paper: the async extension gives ~2x for a single thread.
        let mut sync_cfg = quick_cfg(SystemKind::Lite { async_ops: false }, 2);
        sync_cfg.threads = 1;
        let mut async_cfg = quick_cfg(SystemKind::Lite { async_ops: true }, 2);
        async_cfg.threads = 1;
        let sync = World::new(sync_cfg).run();
        let asyn = World::new(async_cfg).run();
        assert!(
            asyn.per_machine_mops > sync.per_machine_mops * 1.5,
            "async {} vs sync {}",
            asyn.per_machine_mops,
            sync.per_machine_mops
        );
    }

    #[test]
    fn tatp_commits_transactions() {
        let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 4);
        cfg.workload = WorkloadKind::Tatp { subscribers_per_node: 2_000 };
        let r = World::new(cfg).run();
        assert!(r.ops > 500, "commits {}", r.ops);
        assert!(r.abort_rate() < 0.05, "abort rate {}", r.abort_rate());
    }

    #[test]
    fn tatp_with_btree_call_forwarding_commits() {
        // PR 5: CALL_FORWARDING backed by a B-link tree — simulated
        // transactions mix item-granularity (MICA) and leaf-granularity
        // (tree) OCC, including inserts/deletes that write through the
        // tree and GetNewDestination reads validating leaf headers.
        let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 4);
        cfg.workload = WorkloadKind::Tatp { subscribers_per_node: 2_000 };
        cfg.tatp_cf_btree = true;
        let r = World::new(cfg).run();
        assert!(r.ops > 500, "commits {}", r.ops);
        // Leaf-granularity locking raises false conflicts (neighboring
        // CF keys share leaves), but the mix must still commit the bulk.
        assert!(r.abort_rate() < 0.2, "abort rate {}", r.abort_rate());
    }

    #[test]
    fn tatp_btree_cf_deterministic_across_runs() {
        let mk = || {
            let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 3);
            cfg.workload = WorkloadKind::Tatp { subscribers_per_node: 1_000 };
            cfg.tatp_cf_btree = true;
            World::new(cfg).run()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.aborts, b.aborts);
    }

    #[test]
    fn smallbank_commits_transactions() {
        // ROADMAP follow-up from PR 3: the write-heavy SmallBank mix now
        // runs in the simulator too.
        let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 4);
        cfg.workload = WorkloadKind::SmallBank { accounts_per_node: 2_000 };
        let r = World::new(cfg).run();
        assert!(r.ops > 500, "commits {}", r.ops);
        // Four of six tx types write with a hot-account skew, so aborts
        // happen — but the OCC engine must still commit the bulk.
        assert!(r.abort_rate() < 0.3, "abort rate {}", r.abort_rate());
    }

    #[test]
    fn smallbank_runs_on_ud_and_sync_lite_paths() {
        // The mix must survive the window-of-1 transports too: eRPC's UD
        // datagrams and synchronous LITE.
        for system in [
            SystemKind::Erpc { congestion_control: true },
            SystemKind::Lite { async_ops: false },
        ] {
            let mut cfg = quick_cfg(system, 3);
            cfg.workload = WorkloadKind::SmallBank { accounts_per_node: 1_000 };
            let r = World::new(cfg).run();
            // Window-of-1 transports commit far less in the same window;
            // what matters is that the mix runs and commits at all.
            assert!(r.ops > 20, "{system:?} commits {}", r.ops);
        }
    }

    #[test]
    fn smallbank_deterministic_across_runs() {
        let mk = || {
            let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 3);
            cfg.workload = WorkloadKind::SmallBank { accounts_per_node: 1_000 };
            World::new(cfg).run()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.aborts, b.aborts);
    }

    #[test]
    fn replicated_tatp_ships_backup_applies() {
        // Primary-backup replication in the simulator: every committed
        // write ships `r - 1` extra backup-apply RPCs in the commit
        // volley, so rpcs/op must rise against the unreplicated run (the
        // modeled replication wire+CPU tax), while the mix still commits.
        let base_cfg = || {
            let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 3);
            cfg.workload = WorkloadKind::Tatp { subscribers_per_node: 1_000 };
            cfg
        };
        let base = World::new(base_cfg()).run();
        let mut repl_cfg = base_cfg();
        repl_cfg.replication = 2;
        let repl = World::new(repl_cfg).run();
        assert!(repl.ops > 500, "replicated commits {}", repl.ops);
        assert!(repl.abort_rate() < 0.1, "abort rate {}", repl.abort_rate());
        assert!(
            repl.rpcs_per_op > base.rpcs_per_op,
            "replication must ship extra RPCs: {} vs {}",
            repl.rpcs_per_op,
            base.rpcs_per_op
        );
    }

    #[test]
    fn replicated_runs_are_deterministic() {
        let mk = || {
            let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 3);
            cfg.workload = WorkloadKind::SmallBank { accounts_per_node: 1_000 };
            cfg.replication = 2;
            World::new(cfg).run()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.aborts, b.aborts);
        assert_eq!(a.p50_ns, b.p50_ns);
    }

    #[test]
    fn replication_clamps_to_cluster_size() {
        // r = 8 over 3 nodes degrades to full replication, not a panic.
        let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 3);
        cfg.workload = WorkloadKind::Tatp { subscribers_per_node: 500 };
        cfg.replication = 8;
        let r = World::new(cfg).run();
        assert!(r.ops > 100, "commits {}", r.ops);
    }

    #[test]
    fn deterministic_across_runs() {
        let a = World::new(quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 3)).run();
        let b = World::new(quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 3)).run();
        assert_eq!(a.ops, b.ops);
        assert_eq!(a.p50_ns, b.p50_ns);
        assert_eq!(a.aborts, b.aborts);
    }

    #[test]
    fn tatp_range_partitioned_call_forwarding_commits() {
        // PR 3 follow-up: CALL_FORWARDING range-partitioned by subscriber
        // id — loader and resolver agree on the non-hash owner, so the
        // mix commits exactly like the hashed baseline does.
        let mut cfg = quick_cfg(SystemKind::Storm(StormMode::OneTwoSided), 4);
        cfg.workload = WorkloadKind::Tatp { subscribers_per_node: 2_000 };
        cfg.tatp_cf_range = true;
        let r = World::new(cfg).run();
        assert!(r.ops > 500, "commits {}", r.ops);
        assert!(r.abort_rate() < 0.05, "abort rate {}", r.abort_rate());
    }

    #[test]
    fn fanout_cluster_runs_and_reports_telemetry() {
        // Rack scale-out: 2 client machines against a 24-node cluster.
        // Clients spread keys over every node and the NIC sees the whole
        // destination fan-out in its active-QP tracker.
        let mut cfg = quick_cfg(SystemKind::Storm(StormMode::Perfect), 2);
        cfg.fanout_nodes = 24;
        let r = World::new(cfg).run();
        assert!(r.ops > 500, "ops {}", r.ops);
        assert!(r.active_qps > 0, "active-QP telemetry must flow");
        assert_eq!(r.demotions, 0, "static RC never demotes");
        assert_eq!(r.ud_destinations, 0);
    }

    #[test]
    fn qp_share_trades_a_gate_for_fewer_connections() {
        // Multiplexed RC: the run completes, throughput stays in the same
        // ballpark at small scale (the gate is cheap, the cache already
        // fits), and the topology exposes s× fewer connections.
        let base = World::new(quick_cfg(SystemKind::Storm(StormMode::Perfect), 4)).run();
        let mut cfg = quick_cfg(SystemKind::Storm(StormMode::Perfect), 4);
        cfg.qp_share = 2;
        let shared = World::new(cfg).run();
        assert!(shared.ops > 500, "ops {}", shared.ops);
        assert!(
            shared.per_machine_mops > base.per_machine_mops * 0.7,
            "qp_share=2 collapsed throughput: {} vs {}",
            shared.per_machine_mops,
            base.per_machine_mops
        );
    }

    #[test]
    fn static_ud_storm_serves_reads_from_host_cpu() {
        // TransportPolicy::StaticUd degrades every remote read into a
        // datagram read-RPC: the run still resolves lookups (reads are
        // posted, served by CPU) and reports every destination demoted.
        let mut cfg = quick_cfg(SystemKind::Storm(StormMode::Perfect), 4);
        cfg.transport = TransportPolicy::StaticUd;
        let r = World::new(cfg).run();
        assert!(r.ops > 500, "ops {}", r.ops);
        assert!(r.reads_per_op > 0.95, "lookups still post reads");
        assert!(r.retransmits == 0, "no datagrams lost unloaded");
        let rc = World::new(quick_cfg(SystemKind::Storm(StormMode::Perfect), 4)).run();
        assert!(
            rc.per_machine_mops > r.per_machine_mops,
            "at rack scale RC one-sided reads beat the datagram tax: {} vs {}",
            rc.per_machine_mops,
            r.per_machine_mops
        );
    }

    #[test]
    fn adaptive_matches_static_rc_when_cache_is_warm() {
        // Hysteresis guard: a 4-node cluster never pressures the state
        // cache, so the adaptive controller must sit on its hands and
        // reproduce static RC within measurement noise (ISSUE 9 ±5%).
        let mk = |policy| {
            let mut cfg = quick_cfg(SystemKind::Storm(StormMode::Perfect), 4);
            cfg.transport = policy;
            World::new(cfg).run()
        };
        let rc = mk(TransportPolicy::StaticRc);
        let ad = mk(TransportPolicy::Adaptive);
        assert_eq!(ad.demotions, 0, "warm cache must not demote");
        let ratio = ad.per_machine_mops / rc.per_machine_mops;
        assert!(
            (0.95..=1.05).contains(&ratio),
            "adaptive {} vs static RC {} (ratio {ratio})",
            ad.per_machine_mops,
            rc.per_machine_mops
        );
    }

    #[test]
    fn unloaded_latency_storm_read_near_paper() {
        // Table 5: Storm(RR) on CX4 IB = 1.8 us unloaded.
        let mut cfg = SimConfig::new(SystemKind::Storm(StormMode::Perfect), 2);
        cfg.threads = 1;
        cfg.coros = 1;
        cfg.keys_per_node = 2_000;
        cfg.warmup = 50 * MICRO;
        cfg.measure = 1 * MILLI;
        let r = World::new(cfg).run();
        assert!(
            (1_400.0..2_300.0).contains(&r.mean_ns),
            "unloaded RR RTT {} ns, want ~1800",
            r.mean_ns
        );
    }
}
