//! Run configuration for the cluster simulator.
//!
//! `HostParams` carries the calibrated host-side CPU costs (ns). Like the
//! NIC generation constants, they are knobs fitted to the paper's
//! observables (Table 5 RTTs, Fig. 4–6 ratios) rather than measured
//! datasheet values; the calibration tests in `rust/tests/` pin them.

use crate::fabric::FabricKind;
use crate::mem::PageSize;
use crate::nic::NicGen;
use crate::sim::{Nanos, MICRO, MILLI};
use crate::transport::TransportPolicy;

/// Which dataplane design is under test.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// Storm (this paper).
    Storm(StormMode),
    /// eRPC / FaSST-style UD RPC-only system.
    Erpc {
        /// Application-level congestion control enabled?
        congestion_control: bool,
    },
    /// FaRM-style: hopscotch table, large one-sided reads. `locked`
    /// reinstates the original QP-sharing locks (ablation; the paper's
    /// Lockfree_FaRM removes them).
    Farm {
        /// Share QPs between thread groups behind a lock (original FaRM).
        locked_qp_sharing: bool,
    },
    /// LITE-style kernel RDMA. `async_ops` is the paper's Async_LITE
    /// improvement (multiple outstanding ops per thread).
    Lite {
        /// Allow asynchronous (windowed) operations.
        async_ops: bool,
    },
}

/// Storm's three evaluated configurations (Fig. 4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StormMode {
    /// All lookups via write-based RPCs (the "Storm" curve).
    RpcOnly,
    /// One-sided read first, RPC on pointer chase ("Storm (oversub)" when
    /// the table is oversized).
    OneTwoSided,
    /// Reads always suffice — fully warmed client address cache +
    /// oversubscription ("Storm (perfect)").
    Perfect,
}

/// Benchmark workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Random single-key lookups (Fig. 4, 5, 7).
    KvLookups,
    /// TATP transactions (Fig. 6); subscribers scaled per node.
    Tatp {
        /// Subscribers per node.
        subscribers_per_node: u64,
    },
    /// SmallBank transactions (three tables, write-heavy banking mix
    /// with a hot-account skew); accounts scaled per node. Runs on every
    /// transport path the simulator models (RC, UD, sync/async LITE).
    SmallBank {
        /// Customer accounts per node.
        accounts_per_node: u64,
    },
}

/// Calibrated host-side costs (ns unless noted).
#[derive(Clone, Copy, Debug)]
pub struct HostParams {
    /// CPU cost to build + post a WQE.
    pub post_wqe: u32,
    /// PCIe doorbell (MMIO write reaching the NIC).
    pub doorbell_pcie: u32,
    /// CQE DMA + cache-line transfer to the polling host.
    pub cqe_dma: u32,
    /// CQ poll cost per completion.
    pub poll: u32,
    /// Coroutine switch.
    pub coro_switch: u32,
    /// RPC handler base cost (hash, inline bucket probe, reply build).
    pub handler_base: u32,
    /// Extra handler cost per overflow-chain hop.
    pub handler_per_hop: u32,
    /// eRPC: per-message software framing (UD headers, session lookup).
    pub ud_frame_cpu: u32,
    /// eRPC: receive-buffer repost base cost per message.
    pub recv_repost_base: u32,
    /// eRPC: additional repost cost per cluster node (RQ provisioning
    /// grows with peers — the paper's receive-queue scaling problem).
    pub recv_repost_per_node: u32,
    /// LITE: syscall entry/exit (KPTI-era).
    pub lite_syscall: u32,
    /// LITE: kernel work per op under the global lock (mapping lookup,
    /// permission check, post).
    pub lite_kernel_work: u32,
    /// LITE: kernel completion handling (also under the lock).
    pub lite_kernel_completion: u32,
    /// FaRM ablation: lock acquire/release cost for shared QPs.
    pub farm_qp_lock: u32,
    /// FaRM ablation: threads per shared QP group.
    pub farm_qp_group: u32,
    /// QP multiplexing: serialization cost per post on a shared RC send
    /// queue (uncontended CAS + doorbell-record update). Cheaper than
    /// `farm_qp_lock` because Storm's sharing groups sibling threads on
    /// the same core complex.
    pub qp_share_lock: u32,
    /// UD receive pool depth per machine (NIC RQ limit).
    pub recv_pool_capacity: u32,
    /// UD retransmission timeout.
    pub rto: Nanos,
}

impl Default for HostParams {
    fn default() -> Self {
        HostParams {
            post_wqe: 70,
            doorbell_pcie: 200,
            cqe_dma: 200,
            poll: 40,
            coro_switch: 40,
            handler_base: 120,
            handler_per_hop: 90,
            ud_frame_cpu: 90,
            recv_repost_base: 60,
            recv_repost_per_node: 3,
            lite_syscall: 350,
            lite_kernel_work: 650,
            lite_kernel_completion: 350,
            farm_qp_lock: 120,
            farm_qp_group: 4,
            qp_share_lock: 60,
            recv_pool_capacity: 8192,
            rto: 300 * MICRO,
        }
    }
}

/// Full simulation configuration.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// System under test.
    pub system: SystemKind,
    /// Machines.
    pub nodes: u32,
    /// Threads per machine.
    pub threads: u32,
    /// Coroutines per thread (outstanding-op window).
    pub coros: u32,
    /// Wire fabric.
    pub fabric: FabricKind,
    /// NIC generation.
    pub nic: NicGen,
    /// Page size backing data regions.
    pub page_size: PageSize,
    /// Export data memory as physical segments (no MTT).
    pub physseg: bool,
    /// Workload.
    pub workload: WorkloadKind,
    /// KV: keys per node.
    pub keys_per_node: u64,
    /// Target inline occupancy (buckets sized as keys/(occupancy*width)).
    pub occupancy: f64,
    /// Slots per bucket.
    pub bucket_width: u32,
    /// Value bytes (112 -> 128 B transfers).
    pub value_len: u32,
    /// Warmup before measuring.
    pub warmup: Nanos,
    /// Measurement window length.
    pub measure: Nanos,
    /// RNG seed.
    pub seed: u64,
    /// Fig. 7 emulation: parallel connections + buffers multiplier.
    pub conn_multiplier: u32,
    /// Rack scale-out: total cluster size including server-only nodes.
    /// `0` disables fan-out (cluster size is `nodes`). When `> nodes`,
    /// the first `nodes` machines run clients while all `fanout_nodes`
    /// serve data, so each client NIC talks to hundreds of destinations
    /// and RC state pressure materializes without simulating hundreds of
    /// full client machines.
    pub fanout_nodes: u32,
    /// Per-destination transport selection (Storm systems only; the
    /// baselines keep their hard-wired transports).
    pub transport: TransportPolicy,
    /// Threads sharing one RC connection per (pair, channel); 1 = the
    /// paper's private sibling-pair QPs.
    pub qp_share: u32,
    /// Override the NIC SRAM state-cache capacity in bytes (None = the
    /// generation's default). Used to force state-cache pressure in
    /// deterministic degradation tests.
    pub nic_cache_override: Option<u64>,
    /// Per-object placement: range-partition the TATP CALL_FORWARDING
    /// table by subscriber id instead of hashing per row (PR 3 follow-up;
    /// exercises non-uniform routing in the scale-out sweep).
    pub tatp_cf_range: bool,
    /// Ablation: carry Storm RPCs over two-sided send/recv instead of
    /// `rdma_write_with_imm` (paper §5.2 argues write-imm is superior).
    pub rpc_via_sendrecv: bool,
    /// Heterogeneous TATP (PR 5): back the CALL_FORWARDING table with a
    /// B-link tree instead of a MICA table, so simulated transactions
    /// mix item-granularity and leaf-granularity OCC. TATP workload only.
    pub tatp_cf_btree: bool,
    /// Copies of every row (primary-backup replication). 1 = unreplicated.
    /// With `r > 1` each committed write also ships `r - 1` backup-apply
    /// RPCs in the commit volley; the simulator charges their modeled
    /// wire bytes (request framing plus the committed value image) so the
    /// replication bandwidth tax shows up in throughput, clamped to the
    /// cluster size at load time.
    pub replication: u32,
    /// Host cost knobs.
    pub host: HostParams,
}

impl SimConfig {
    /// A sane default: Storm(oversub) on the CX4 IB cluster.
    pub fn new(system: SystemKind, nodes: u32) -> Self {
        SimConfig {
            system,
            nodes,
            threads: 8,
            coros: 8,
            fabric: FabricKind::IbEdr,
            nic: NicGen::Cx4,
            page_size: PageSize::Huge2M,
            physseg: false,
            workload: WorkloadKind::KvLookups,
            keys_per_node: 60_000,
            occupancy: 0.6,
            bucket_width: 1,
            value_len: 112,
            warmup: 500 * MICRO,
            measure: 2 * MILLI,
            seed: 0x5701_2019,
            conn_multiplier: 1,
            fanout_nodes: 0,
            transport: TransportPolicy::StaticRc,
            qp_share: 1,
            nic_cache_override: None,
            tatp_cf_range: false,
            rpc_via_sendrecv: false,
            tatp_cf_btree: false,
            replication: 1,
            host: HostParams::default(),
        }
    }

    /// Buckets per node implied by keys/occupancy/width (power of two).
    pub fn buckets_per_node(&self, keys_per_node: u64) -> u64 {
        let target = (keys_per_node as f64 / (self.occupancy * self.bucket_width as f64)).ceil();
        (target as u64).max(2).next_power_of_two()
    }

    /// Cluster size including fan-out server-only nodes.
    pub fn total_nodes(&self) -> u32 {
        self.nodes.max(self.fanout_nodes)
    }

    /// Total keyspace for the KV workload (spread over the full cluster,
    /// including fan-out nodes).
    pub fn total_keys(&self) -> u64 {
        self.keys_per_node * self.total_nodes() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_sizing_respects_occupancy() {
        let mut cfg = SimConfig::new(SystemKind::Storm(StormMode::OneTwoSided), 4);
        cfg.occupancy = 0.5;
        cfg.bucket_width = 1;
        let b = cfg.buckets_per_node(60_000);
        assert!(b.is_power_of_two());
        assert!(b >= 120_000 / 2); // at least keys/occupancy rounded up
        // High occupancy (the paper's plain "Storm" sizing): fewer buckets.
        cfg.occupancy = 2.0;
        assert!(cfg.buckets_per_node(60_000) < b);
    }

    #[test]
    fn default_is_paper_testbed() {
        let cfg = SimConfig::new(SystemKind::Erpc { congestion_control: true }, 16);
        assert_eq!(cfg.fabric, FabricKind::IbEdr);
        assert_eq!(cfg.nic, NicGen::Cx4);
        assert_eq!(cfg.total_keys(), 16 * 60_000);
        assert_eq!(cfg.transport, TransportPolicy::StaticRc);
        assert_eq!(cfg.qp_share, 1);
    }

    #[test]
    fn fanout_extends_cluster_and_keyspace() {
        let mut cfg = SimConfig::new(SystemKind::Storm(StormMode::Perfect), 4);
        assert_eq!(cfg.total_nodes(), 4);
        cfg.fanout_nodes = 64;
        assert_eq!(cfg.total_nodes(), 64);
        assert_eq!(cfg.total_keys(), 64 * cfg.keys_per_node);
    }
}
