//! Cluster simulation: the discrete-event world tying every substrate
//! together.
//!
//! [`config`] describes a run (system under test, cluster shape, NIC
//! generation, fabric, workload, calibrated host CPU costs); [`world`]
//! executes it — every verb flows host CPU → doorbell → NIC PUs (with
//! state-cache charging) → wire → remote NIC → host, with Storm and the
//! three baselines (eRPC, Lockfree_FaRM, Async_LITE) differing exactly
//! where the paper says they differ; [`report`] summarizes throughput,
//! latency and resource counters for the figure harnesses.

pub mod config;
pub mod report;
pub mod world;

pub use config::{HostParams, SimConfig, StormMode, SystemKind, WorkloadKind};
pub use report::{AbortCounts, ClientLatency, LaneGauges, LiveServed, RunReport};
pub use world::World;
