//! # Storm: a fast transactional dataplane for remote data structures
//!
//! Reproduction of *Storm* (Novakovic et al., 2019): a transactional RDMA
//! dataplane built on one-sided reads and write-based RPCs over reliably
//! connected (RC) queue pairs, evaluated against eRPC, FaRM, and LITE.
//!
//! Because RDMA NICs and an InfiniBand cluster are not available, the
//! hardware substrate is a calibrated discrete-event model (see
//! [`nic`], [`fabric`], and DESIGN.md §2). The dataplane itself
//! ([`dataplane`], [`ds`]) is *sans-io*: the same transaction engine and
//! data-structure callbacks run on the simulated fabric (for the paper's
//! figures) and on a live in-process thread fabric (for the end-to-end
//! examples, with ring-buffer RPC slots and the AOT-compiled XLA batch
//! engine on the hot path).
//!
//! Layer map (see DESIGN.md):
//! * **L3 (this crate)** — coordinator: Storm dataplane, transports, NIC
//!   model, baselines, workloads, benches.
//! * **L2 (python/compile/model.py)** — batched lookup-resolve and
//!   validation graphs in JAX, AOT-lowered to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels called by L2.
//!
//! The [`runtime`] module loads the AOT artifacts through PJRT and exposes
//! them to the L3 hot path; python never runs at request time.

pub mod bench;
pub mod cluster;
pub mod dataplane;
pub mod ds;
pub mod fabric;
pub mod mem;
pub mod nic;
pub mod runtime;
pub mod sim;
pub mod transport;
pub mod workload;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
