//! Live in-process fabric: RDMA-like primitives over shared memory +
//! threads.
//!
//! Used by the end-to-end examples: the same Storm dataplane logic that the
//! simulator drives (sans-io transaction engine, MICA table, callback API)
//! runs here against *real* memory and *real* channels, in wall-clock time,
//! with the PJRT batch-hash engine on the lookup path.
//!
//! Semantics mirror the verbs we model:
//! * `read` — one-sided: no code runs on the remote node's event loop,
//!   just a direct memory copy (an RDMA READ against registered memory).
//! * `rpc` — write-with-immediate style messaging: the payload lands in
//!   the remote node's receive loop, a registered handler runs, and the
//!   reply travels back on the caller's completion channel.

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, RwLock};

use crate::mem::MrKey;

/// A registered memory region on a loopback node.
#[derive(Clone)]
pub struct LoopbackRegion {
    bytes: Arc<RwLock<Vec<u8>>>,
}

impl LoopbackRegion {
    /// Region of `len` zero bytes.
    pub fn new(len: usize) -> Self {
        LoopbackRegion { bytes: Arc::new(RwLock::new(vec![0; len])) }
    }

    /// One-sided read (no remote CPU).
    pub fn read(&self, offset: usize, len: usize) -> Vec<u8> {
        let g = self.bytes.read().unwrap();
        g[offset..offset + len].to_vec()
    }

    /// One-sided write (no remote CPU).
    pub fn write(&self, offset: usize, data: &[u8]) {
        let mut g = self.bytes.write().unwrap();
        g[offset..offset + data.len()].copy_from_slice(data);
    }

    /// Region length.
    pub fn len(&self) -> usize {
        self.bytes.read().unwrap().len()
    }

    /// True when zero-length.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// An inbound RPC awaiting a reply.
pub struct RpcEnvelope {
    /// Sender node id.
    pub from: u32,
    /// Request payload (header + body, see [`crate::dataplane::rpc`]).
    pub payload: Vec<u8>,
    /// Reply channel (the "response write" back to the requester).
    pub reply: Sender<Vec<u8>>,
}

#[derive(Clone)]
struct EndpointShared {
    regions: Vec<LoopbackRegion>,
    rpc_tx: SyncSender<RpcEnvelope>,
}

/// Handle to all nodes (what a "connected QP mesh" gives you).
#[derive(Clone)]
pub struct LoopbackFabric {
    endpoints: Arc<Vec<EndpointShared>>,
}

impl LoopbackFabric {
    /// Build a fabric of `nodes` endpoints, each with the given region
    /// sizes registered. Returns the fabric handle plus, per node, the
    /// RPC receive queue its event loop drains.
    pub fn new(nodes: u32, region_sizes: &[usize]) -> (Self, Vec<Receiver<RpcEnvelope>>) {
        let mut shared = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..nodes {
            let regions: Vec<LoopbackRegion> =
                region_sizes.iter().map(|&l| LoopbackRegion::new(l)).collect();
            // Bounded like a receive queue: senders block when the RQ is
            // full (RC write-with-imm backpressure, not UD drops).
            let (tx, rx) = sync_channel(4096);
            shared.push(EndpointShared { regions, rpc_tx: tx });
            rxs.push(rx);
        }
        (LoopbackFabric { endpoints: Arc::new(shared) }, rxs)
    }

    /// One-sided read of `(region, offset, len)` on `node`.
    pub fn read(&self, node: u32, region: MrKey, offset: u64, len: u32) -> Vec<u8> {
        self.endpoints[node as usize].regions[region.0 as usize]
            .read(offset as usize, len as usize)
    }

    /// One-sided write to `(region, offset)` on `node`.
    pub fn write(&self, node: u32, region: MrKey, offset: u64, data: &[u8]) {
        self.endpoints[node as usize].regions[region.0 as usize].write(offset as usize, data);
    }

    /// Write-based RPC to `node`: delivers `payload`, blocks for the
    /// handler's reply. Returns `None` when the remote event loop is gone.
    pub fn rpc(&self, from: u32, node: u32, payload: Vec<u8>) -> Option<Vec<u8>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.endpoints[node as usize]
            .rpc_tx
            .send(RpcEnvelope { from, payload, reply: reply_tx })
            .ok()?;
        reply_rx.recv().ok()
    }

    /// Fire-and-forget message to a node's RPC queue (control messages;
    /// the reply channel is dropped immediately).
    pub fn send_raw(&self, from: u32, node: u32, payload: Vec<u8>) {
        let (reply_tx, _reply_rx) = std::sync::mpsc::channel();
        let _ = self.endpoints[node as usize]
            .rpc_tx
            .send(RpcEnvelope { from, payload, reply: reply_tx });
    }

    /// Direct handle to a node's region (loading data in place).
    pub fn region(&self, node: u32, r: MrKey) -> LoopbackRegion {
        self.endpoints[node as usize].regions[r.0 as usize].clone()
    }

    /// Number of nodes.
    pub fn nodes(&self) -> u32 {
        self.endpoints.len() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn one_sided_read_write_roundtrip() {
        let (fabric, _rxs) = LoopbackFabric::new(2, &[4096]);
        fabric.write(1, MrKey(0), 100, b"storm");
        assert_eq!(&fabric.read(1, MrKey(0), 100, 5), b"storm");
        // Node 0's memory untouched.
        assert_eq!(fabric.read(0, MrKey(0), 100, 5), vec![0; 5]);
    }

    #[test]
    fn rpc_roundtrip_through_handler() {
        let (fabric, mut rxs) = LoopbackFabric::new(2, &[64]);
        let rx = rxs.remove(1);
        let h = thread::spawn(move || {
            // Serve exactly one request, echo reversed.
            let env = rx.recv().unwrap();
            let mut reply = env.payload.clone();
            reply.reverse();
            env.reply.send(reply).unwrap();
        });
        let resp = fabric.rpc(0, 1, vec![1, 2, 3]).unwrap();
        assert_eq!(resp, vec![3, 2, 1]);
        h.join().unwrap();
    }

    #[test]
    fn concurrent_rpcs_all_answered() {
        let (fabric, mut rxs) = LoopbackFabric::new(2, &[64]);
        let rx = rxs.remove(1);
        let server = thread::spawn(move || {
            let mut served = 0;
            while served < 64 {
                let env = rx.recv().unwrap();
                env.reply.send(env.payload).unwrap();
                served += 1;
            }
        });
        let mut handles = Vec::new();
        for i in 0..64u8 {
            let f = fabric.clone();
            handles.push(thread::spawn(move || f.rpc(0, 1, vec![i]).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), vec![i as u8]);
        }
        server.join().unwrap();
    }

    #[test]
    fn rpc_to_dead_node_returns_none() {
        let (fabric, rxs) = LoopbackFabric::new(2, &[64]);
        drop(rxs); // no event loops
        assert_eq!(fabric.rpc(0, 1, vec![1]), None);
    }
}
