//! Live in-process fabric: RDMA-like primitives over shared memory +
//! threads, **lock-free on the steady-state data path**.
//!
//! Used by the live dataplane: the same Storm protocol logic the
//! simulator drives (sans-io transaction engine, MICA table, callback
//! API) runs here against *real* memory and *real* threads, in
//! wall-clock time.
//!
//! Semantics mirror the verbs we model:
//!
//! * `read` / `read_into` / `read_batch` — one-sided: no code runs on the
//!   remote node's event loop, just a direct memory copy (an RDMA READ
//!   against registered memory). Region bytes are `AtomicU8`s accessed
//!   with `Relaxed` per-byte loads/stores: remote reads may observe
//!   **torn** images while an owner is mirroring — exactly the fidelity
//!   real RDMA gives — without undefined behavior; OCC version
//!   validation is the dataplane's correctness mechanism, not read
//!   atomicity. `read_batch` is the doorbell-batched variant: one pass
//!   copies every request of a group into a caller-owned scratch buffer
//!   (no allocation on the steady state), the way one doorbell ring
//!   posts a chain of work requests.
//! * ring RPCs ([`RingConn`]) — write-with-immediate style messaging into
//!   **preallocated ring-buffer slots**: `post` frames the request
//!   directly into a reusable slot buffer (no per-call allocation), the
//!   remote reactor runs the handler and writes the reply into the same
//!   slot's reply buffer, and the caller harvests it with
//!   `poll`/`take_reply`. Each slot is a lock-free stage machine
//!   (`FREE → POSTED → SERVING → DONE`): exactly one side owns the
//!   buffers at every stage, handoff is a single atomic transition, and
//!   completion unparks the posting thread. A [`RingConn`] is
//!   **single-owner** (`&mut self` to post/harvest) — the per-thread QP
//!   of the paper; clients that want more parallelism open more
//!   connections, one per thread.
//! * receive **lanes** ([`LaneRx`]) — each endpoint exposes one receive
//!   lane per server shard, drained by exactly one reactor thread.
//!   Inbound slot traffic arrives over bounded **lock-free SPSC rings**
//!   ([`SpscRing`]), one per (connection, lane) pair, registered at
//!   connect time; the reactor round-robins over its rings with plain
//!   atomic loads. One-shot messages (`rpc`, `send_raw`, shutdown
//!   poison) travel a mutexed control queue — that is the documented
//!   control plane, never the data path.
//! * idle shards **park** instead of spinning: a [`Waker`] per lane
//!   carries the reactor's thread handle; producers wake it after
//!   publishing work, and the reactor re-checks every source after
//!   announcing sleep (plus a short `park_timeout` bound as
//!   defense-in-depth), so no wakeup is ever lost.
//! * `rpc` — legacy blocking convenience over a one-shot channel (tests,
//!   control paths, replies of unbounded size). The dataplane hot path
//!   uses ring slots.

use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex, OnceLock}; // Mutex: control-plane queues only (see module doc)
use std::thread::Thread;
use std::time::Duration;

use crate::mem::MrKey;

/// Bounded lock-free single-producer single-consumer ring. The transport
/// primitive of the shared-nothing dataplane: one producer thread
/// `push`es, one consumer thread `pop`s, nobody locks.
///
/// Capacity rounds up to a power of two. `push` fails (returning the
/// value) when the ring is full — bounded backpressure, not drops.
///
/// # Safety contract
///
/// The ring itself is `Sync`, but the lock-freedom argument requires the
/// single-producer / single-consumer discipline: at most one thread ever
/// calls `push`, at most one thread ever calls `pop`. Slot `i & mask` is
/// owned by the producer from the moment `head` has passed it until the
/// matching `tail` store publishes it, and by the consumer from that
/// publication until its `head` store returns it — the two `Release` /
/// `Acquire` pairs on `tail` and `head` carry the handoff.
pub struct SpscRing<T> {
    slots: Box<[UnsafeCell<Option<T>>]>,
    mask: usize,
    /// Consumer cursor: next index to pop.
    head: AtomicUsize,
    /// Producer cursor: next index to fill.
    tail: AtomicUsize,
}

// SAFETY: slot access is mediated by the head/tail cursors with
// Release/Acquire ordering under the SPSC discipline documented above.
unsafe impl<T: Send> Send for SpscRing<T> {}
unsafe impl<T: Send> Sync for SpscRing<T> {}

impl<T> SpscRing<T> {
    /// Ring with room for at least `capacity` items.
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(2).next_power_of_two();
        let slots: Box<[UnsafeCell<Option<T>>]> =
            (0..cap).map(|_| UnsafeCell::new(None)).collect();
        SpscRing { slots, mask: cap - 1, head: AtomicUsize::new(0), tail: AtomicUsize::new(0) }
    }

    /// Number of slots.
    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Producer side: enqueue `v`, or hand it back when the ring is full.
    pub fn push(&self, v: T) -> Result<(), T> {
        let tail = self.tail.load(Ordering::Relaxed);
        let head = self.head.load(Ordering::Acquire);
        if tail.wrapping_sub(head) == self.slots.len() {
            return Err(v);
        }
        // SAFETY: `head` has advanced past this slot (checked above), so
        // the consumer is done with it; we are the only producer.
        unsafe { *self.slots[tail & self.mask].get() = Some(v) };
        self.tail.store(tail.wrapping_add(1), Ordering::Release);
        Ok(())
    }

    /// Consumer side: dequeue the oldest item, if any.
    pub fn pop(&self) -> Option<T> {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Acquire);
        if head == tail {
            return None;
        }
        // SAFETY: `tail` has been published past this slot (checked
        // above), so the producer's write is visible; we are the only
        // consumer.
        let v = unsafe { (*self.slots[head & self.mask].get()).take() };
        self.head.store(head.wrapping_add(1), Ordering::Release);
        v
    }

    /// True when nothing is queued (either side may ask).
    pub fn is_empty(&self) -> bool {
        self.head.load(Ordering::Acquire) == self.tail.load(Ordering::Acquire)
    }
}

/// Park/unpark rendezvous for one reactor thread: producers `wake` after
/// publishing work, the reactor announces sleep, re-checks its sources,
/// and parks. The `SeqCst` store/fence pairing makes the classic
/// lost-wakeup race impossible: either the producer observes `asleep`
/// and unparks (an unpark before the park charges a token the park
/// consumes immediately), or the reactor's post-announce re-check
/// observes the freshly published work. Reactors additionally park with
/// a short timeout as defense-in-depth.
pub struct Waker {
    asleep: AtomicBool,
    thread: OnceLock<Thread>,
}

impl Waker {
    /// New waker; the reactor registers its thread with
    /// [`Self::register_current`] before first use.
    pub fn new() -> Self {
        Waker { asleep: AtomicBool::new(false), thread: OnceLock::new() }
    }

    /// Bind this waker to the calling thread (the reactor).
    pub fn register_current(&self) {
        let _ = self.thread.set(std::thread::current());
    }

    /// Producer side: unpark the reactor if it announced sleep. Call
    /// *after* publishing work (the fence orders the publication before
    /// the `asleep` read).
    pub fn wake(&self) {
        fence(Ordering::SeqCst);
        if self.asleep.load(Ordering::SeqCst) {
            if let Some(t) = self.thread.get() {
                t.unpark();
            }
        }
    }

    /// Reactor side: announce intent to sleep. Follow with a re-check of
    /// every work source, then [`std::thread::park_timeout`], then
    /// [`Self::end_sleep`].
    pub fn begin_sleep(&self) {
        self.asleep.store(true, Ordering::SeqCst);
        fence(Ordering::SeqCst);
    }

    /// Reactor side: done sleeping.
    pub fn end_sleep(&self) {
        self.asleep.store(false, Ordering::SeqCst);
    }
}

impl Default for Waker {
    fn default() -> Self {
        Self::new()
    }
}

/// A registered memory region on a loopback node: a flat byte array of
/// `AtomicU8`s. All access is `Relaxed` per byte — one-sided reads racing
/// an owner's mirror writes may observe torn images (RDMA fidelity, and
/// deliberately UB-free); the dataplane's OCC version protocol is what
/// makes reads correct, not byte-level atomicity.
#[derive(Clone)]
pub struct LoopbackRegion {
    bytes: Arc<Vec<AtomicU8>>,
}

impl LoopbackRegion {
    /// Region of `len` zero bytes.
    pub fn new(len: usize) -> Self {
        LoopbackRegion { bytes: Arc::new((0..len).map(|_| AtomicU8::new(0)).collect()) }
    }

    /// One-sided read (no remote CPU). Allocates; prefer [`Self::read_into`]
    /// on hot paths.
    pub fn read(&self, offset: usize, len: usize) -> Vec<u8> {
        let mut out = vec![0u8; len];
        self.read_into(offset, &mut out);
        out
    }

    /// One-sided read into a caller-provided buffer (no allocation).
    pub fn read_into(&self, offset: usize, out: &mut [u8]) {
        for (i, b) in out.iter_mut().enumerate() {
            *b = self.bytes[offset + i].load(Ordering::Relaxed);
        }
    }

    /// Doorbell-batched one-sided reads: every `(offset, len)` request is
    /// copied into `scratch` in one pass (resized, never reallocated on
    /// the steady state once warm), then `f(i, bytes)` observes request
    /// `i`'s bytes in place — zero per-request allocation.
    pub fn read_many(
        &self,
        reqs: &[(u64, u32)],
        scratch: &mut Vec<u8>,
        mut f: impl FnMut(usize, &[u8]),
    ) {
        let total: usize = reqs.iter().map(|&(_, len)| len as usize).sum();
        scratch.clear();
        scratch.resize(total, 0);
        let mut at = 0usize;
        for &(offset, len) in reqs {
            self.read_into(offset as usize, &mut scratch[at..at + len as usize]);
            at += len as usize;
        }
        let mut at = 0usize;
        for (i, &(_, len)) in reqs.iter().enumerate() {
            f(i, &scratch[at..at + len as usize]);
            at += len as usize;
        }
    }

    /// One-sided write (no remote CPU).
    pub fn write(&self, offset: usize, data: &[u8]) {
        for (i, &b) in data.iter().enumerate() {
            self.bytes[offset + i].store(b, Ordering::Relaxed);
        }
    }

    /// Region length.
    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    /// True when zero-length.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Slot stage machine: who owns the request/reply buffers right now.
/// `FREE` — the posting client; `POSTED` — nobody mutates (published
/// request in flight); `SERVING` — exactly one completer (the winner of
/// the `POSTED → SERVING` CAS); `DONE` — the client again.
const STAGE_FREE: u8 = 0;
const STAGE_POSTED: u8 = 1;
const STAGE_SERVING: u8 = 2;
const STAGE_DONE: u8 = 3;

/// The reusable buffers of one ring slot. Plain (non-atomic) fields:
/// ownership transfers with the slot's stage word.
struct SlotBufs {
    /// Request bytes, framed in place by the poster.
    req: Vec<u8>,
    /// Reply bytes, written in place by the completer.
    resp: Vec<u8>,
    /// 32-bit immediate attached by the poster (`rdma_write_with_imm`'s
    /// immediate value): carries the poster's correlation cookie to the
    /// responder without parsing the payload.
    imm: u32,
}

/// One preallocated ring-buffer slot of a [`RingConn`]: the request and
/// reply buffers are reused across RPCs, so steady-state messaging does
/// not allocate — and the post → serve → harvest handoff is a lock-free
/// atomic stage machine.
pub struct RingSlot {
    /// Sender node id (constant for the connection).
    from: u32,
    /// `STAGE_*` word; every transition into `SERVING` is an exclusive
    /// CAS, so at most one party ever completes a posted slot.
    stage: AtomicU8,
    bufs: UnsafeCell<SlotBufs>,
    /// The posting thread, unparked on completion. Captured at connect
    /// time; if the connection later migrates threads, completion still
    /// lands — the poster's wait loop re-checks on a short park timeout.
    waiter: Thread,
}

// SAFETY: `bufs` is accessed only by the party the `stage` word assigns
// ownership to (see the STAGE_* docs); stage transitions use
// Release/Acquire (and CAS for the contended POSTED → SERVING edge), so
// buffer writes are visible to the next owner.
unsafe impl Send for RingSlot {}
unsafe impl Sync for RingSlot {}

impl RingSlot {
    /// Complete a posted-but-unserved slot with an **empty** reply. Used
    /// by both teardown paths (a dropped server handle, a client that
    /// observed the lane close under its posted request); the CAS makes
    /// the completion exclusive against a racing server.
    fn complete_empty(&self) {
        if self
            .stage
            .compare_exchange(STAGE_POSTED, STAGE_SERVING, Ordering::Acquire, Ordering::Relaxed)
            .is_ok()
        {
            // SAFETY: the CAS won SERVING — we are the sole owner.
            unsafe { (*self.bufs.get()).resp.clear() };
            self.stage.store(STAGE_DONE, Ordering::Release);
            self.waiter.unpark();
        }
    }
}

/// The server's owning handle to one posted ring slot. Dropping it
/// unserved (e.g. a reactor exiting with requests still queued, or a
/// crashed node dropping envelopes) completes the slot with an **empty
/// reply**, so the posting client observes a decode failure instead of
/// blocking forever on the slot.
pub struct SlotHandle(Arc<RingSlot>);

impl SlotHandle {
    /// Sender node id.
    pub fn from(&self) -> u32 {
        self.0.from
    }

    /// Immediate value the poster attached (see [`RingConn::post_imm`]).
    pub fn imm(&self) -> u32 {
        // SAFETY: holding the handle means the slot is POSTED (or
        // SERVING under us); the poster published `bufs` before the
        // envelope and will not touch them again until DONE.
        unsafe { (*self.0.bufs.get()).imm }
    }

    /// Observe the posted request bytes without serving — the receive
    /// path's routing peek (e.g. steering a slot to its owning shard by
    /// the object id at its fixed wire offset). Must not be called from
    /// inside [`Self::serve`]'s closure.
    pub fn peek<R>(&self, f: impl FnOnce(&[u8]) -> R) -> R {
        // SAFETY: as in `imm` — the poster is hands-off while POSTED.
        f(unsafe { &(*self.0.bufs.get()).req })
    }

    /// Run `f(request_bytes, reply_buffer)` and complete the slot. The
    /// reply buffer is cleared first; `f` frames the response directly
    /// into it (no allocation once warm). A no-op if the slot was
    /// already completed (a teardown path won the CAS first).
    pub fn serve(&self, f: impl FnOnce(&[u8], &mut Vec<u8>)) {
        let slot = &*self.0;
        if slot
            .stage
            .compare_exchange(STAGE_POSTED, STAGE_SERVING, Ordering::Acquire, Ordering::Relaxed)
            .is_err()
        {
            return;
        }
        // SAFETY: the CAS won SERVING — exclusive buffer ownership.
        let bufs = unsafe { &mut *slot.bufs.get() };
        bufs.resp.clear();
        let SlotBufs { req, resp, .. } = bufs;
        f(req, resp);
        slot.stage.store(STAGE_DONE, Ordering::Release);
        slot.waiter.unpark();
    }
}

impl Drop for SlotHandle {
    fn drop(&mut self) {
        self.0.complete_empty();
    }
}

/// Handle to an outstanding ring RPC (an index into the connection's
/// slot ring).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotToken(usize);

/// How long a poster spins on a completion before parking, and the park
/// bound that covers waiter-thread staleness (see [`RingSlot::waiter`]).
const WAIT_SPINS: u32 = 256;
const WAIT_PARK: Duration = Duration::from_millis(1);

/// One registered producer ring on a receive lane, plus the lane handle
/// for open-checks and wakeups.
struct LaneProducer {
    lane: Arc<Lane>,
    ring: Arc<SpscRing<RpcEnvelope>>,
}

/// A client's ring-buffer connection to one server node: a fixed window
/// of reusable request/reply slots (the paper's preallocated per-sender
/// ring at the receiver), posted over per-lane SPSC rings.
///
/// **Single-owner**: posting and harvesting take `&mut self`. Slots are
/// freed only by [`Self::take_reply`] on the owning thread, so a `post`
/// on a full ring could never unblock — it panics instead; schedulers
/// that interleave posting with harvesting use [`Self::try_post_imm`]
/// and retry after a harvest.
pub struct RingConn {
    slots: Vec<Arc<RingSlot>>,
    /// Free slot indices (plain — single owner).
    free: Vec<usize>,
    /// Lane each outstanding slot was posted on (closed-lane reclaim).
    lane_of: Vec<u32>,
    /// One producer ring per receive lane of the target node.
    lanes: Vec<LaneProducer>,
}

impl RingConn {
    /// Number of slots (the maximum outstanding window).
    pub fn window(&self) -> usize {
        self.slots.len()
    }

    /// Post a request on `lane`, framing it directly into a free slot's
    /// request buffer via `fill`. **Panics when the ring is full** —
    /// slots free only via [`Self::take_reply`] on this same thread, so
    /// blocking could never succeed. Keep the posting window below the
    /// ring size, or use [`Self::try_post`].
    pub fn post(&mut self, lane: u32, fill: impl FnOnce(&mut Vec<u8>)) -> SlotToken {
        self.post_imm(lane, 0, fill)
    }

    /// [`Self::post`] with a 32-bit immediate: the write-with-immediate
    /// value the responder observes alongside the slot (correlation
    /// cookies for multiplexed posters).
    pub fn post_imm(&mut self, lane: u32, imm: u32, fill: impl FnOnce(&mut Vec<u8>)) -> SlotToken {
        let idx = self
            .free
            .pop()
            .expect("ring full: slots free only via take_reply on this thread; bound the window");
        self.submit(idx, lane, imm, fill);
        SlotToken(idx)
    }

    /// Non-blocking [`Self::post`]: `None` when the ring is full.
    pub fn try_post(&mut self, lane: u32, fill: impl FnOnce(&mut Vec<u8>)) -> Option<SlotToken> {
        self.try_post_imm(lane, 0, fill)
    }

    /// Non-blocking [`Self::post_imm`]: `None` when the ring is full.
    /// Posters that also harvest replies on the same thread queue on
    /// `None` and retry after harvesting.
    pub fn try_post_imm(
        &mut self,
        lane: u32,
        imm: u32,
        fill: impl FnOnce(&mut Vec<u8>),
    ) -> Option<SlotToken> {
        let idx = self.free.pop()?;
        self.submit(idx, lane, imm, fill);
        Some(SlotToken(idx))
    }

    fn submit(&mut self, idx: usize, lane: u32, imm: u32, fill: impl FnOnce(&mut Vec<u8>)) {
        let slot = &self.slots[idx];
        {
            // SAFETY: the slot came off the free list, so its stage is
            // FREE and this (single-owner) thread owns the buffers.
            let bufs = unsafe { &mut *slot.bufs.get() };
            bufs.req.clear();
            fill(&mut bufs.req);
            bufs.imm = imm;
        }
        self.lane_of[idx] = lane;
        // Publish: buffer writes happen-before the POSTED store, which
        // happens-before the SPSC push the consumer Acquire-loads.
        slot.stage.store(STAGE_POSTED, Ordering::Release);
        let lp = &self.lanes[lane as usize];
        if !lp.lane.open.load(Ordering::SeqCst) {
            // Lane torn down (server gone): complete client-side with an
            // empty reply so the poster observes a decode failure — the
            // flushed-work-request analog — instead of hanging.
            slot.complete_empty();
            return;
        }
        if lp.ring.push(RpcEnvelope::Slot(SlotHandle(slot.clone()))).is_err() {
            // Unreachable by construction: the producer ring holds at
            // least `window` envelopes and at most `window` slots are
            // outstanding. A dropped envelope still self-completes the
            // slot empty, so a bug degrades to a failed RPC, not a hang.
            debug_assert!(false, "producer ring overflow despite window bound");
        }
        lp.lane.wake();
    }

    /// Has the reply for `tok` arrived? (Non-blocking completion poll.)
    pub fn poll(&self, tok: SlotToken) -> bool {
        self.slots[tok.0].stage.load(Ordering::Acquire) == STAGE_DONE
    }

    /// Block until the reply for `tok` has arrived (does not free the
    /// slot; follow with [`Self::take_reply`]). Bounded spin, then
    /// park — the completer unparks this thread.
    pub fn wait(&self, tok: SlotToken) {
        let slot = &self.slots[tok.0];
        let mut spins = 0u32;
        loop {
            if slot.stage.load(Ordering::Acquire) == STAGE_DONE {
                return;
            }
            if spins < WAIT_SPINS {
                spins += 1;
                std::hint::spin_loop();
                continue;
            }
            // The serving lane may have closed after our post's open
            // check (teardown race): its drained rings will never serve
            // this slot, so reclaim it ourselves. The CAS in
            // `complete_empty` is exclusive against a racing server.
            let lp = &self.lanes[self.lane_of[tok.0] as usize];
            if !lp.lane.open.load(Ordering::SeqCst) {
                slot.complete_empty();
                continue;
            }
            std::thread::park_timeout(WAIT_PARK);
        }
    }

    /// Wait for the reply to `tok`, observe its bytes in place via `f`,
    /// and return the slot to the free ring.
    pub fn take_reply<R>(&mut self, tok: SlotToken, f: impl FnOnce(&[u8]) -> R) -> R {
        self.wait(tok);
        let slot = &self.slots[tok.0];
        // SAFETY: stage is DONE (Acquire-observed in `wait`), so buffer
        // ownership is back with this (single-owner) thread.
        let r = f(unsafe { &(*slot.bufs.get()).resp });
        slot.stage.store(STAGE_FREE, Ordering::Relaxed);
        self.free.push(tok.0);
        r
    }
}

/// An inbound message on a node's receive lane.
pub enum RpcEnvelope {
    /// One-shot message (legacy `rpc`, control traffic). `reply` is `None`
    /// for fire-and-forget sends — no throwaway channel is allocated.
    Message {
        /// Sender node id.
        from: u32,
        /// Request payload (header + body, see [`crate::dataplane::rpc`]).
        payload: Vec<u8>,
        /// Reply channel, when the sender blocks for a response.
        reply: Option<Sender<Vec<u8>>>,
    },
    /// Ring-slot request: the payload sits in the slot's request buffer
    /// and the handler writes the reply back into the same slot.
    Slot(SlotHandle),
}

/// The shared half of one receive lane. Steady-state traffic flows
/// through the registered SPSC rings and touches only atomics; the
/// mutexed registry and control queue are documented control-plane
/// paths (connect, one-shot messages, teardown).
struct Lane {
    /// Registered producer rings. Locked on connect and on a consumer
    /// snapshot refresh only.
    rings: Mutex<Vec<Arc<SpscRing<RpcEnvelope>>>>, // control-plane: connect registration
    /// Bumped per registration; [`LaneRx`] refreshes its snapshot on
    /// change (a plain atomic load on the steady state).
    version: AtomicU64,
    /// One-shot control messages (`rpc`, `send_raw`, shutdown poison).
    ctl: Mutex<VecDeque<RpcEnvelope>>, // control-plane: one-shot message queue
    /// Cheap emptiness probe for `ctl` (steady state never locks it).
    ctl_len: AtomicUsize,
    /// Cleared when the lane's receiver is dropped: posters observe a
    /// dead lane and fail fast instead of queueing into the void.
    open: AtomicBool,
    /// The draining reactor's waker, installed at cluster start.
    waker: OnceLock<Arc<Waker>>,
}

impl Lane {
    fn new() -> Self {
        Lane {
            rings: Mutex::new(Vec::new()), // control-plane: connect registration
            version: AtomicU64::new(0),
            ctl: Mutex::new(VecDeque::new()), // control-plane: one-shot message queue
            ctl_len: AtomicUsize::new(0),
            open: AtomicBool::new(true),
            waker: OnceLock::new(),
        }
    }

    fn wake(&self) {
        if let Some(w) = self.waker.get() {
            w.wake();
        }
    }

    /// Register a new producer ring (a connection opening). Control
    /// plane: locks the registry, bumps the version consumers watch.
    fn register(&self, capacity: usize) -> Arc<SpscRing<RpcEnvelope>> {
        let ring = Arc::new(SpscRing::new(capacity));
        self.rings.lock().unwrap().push(ring.clone()); // control-plane: connect registration
        self.version.fetch_add(1, Ordering::Release);
        ring
    }

    /// Enqueue a one-shot message; `false` when the lane is closed. The
    /// open-check happens under the queue lock, so a message either
    /// lands before the teardown drain (and is drained, dropping its
    /// reply sender) or observes the lane closed — never stranded.
    fn send_ctl(&self, env: RpcEnvelope) -> bool {
        {
            let mut q = self.ctl.lock().unwrap(); // control-plane: one-shot message queue
            if !self.open.load(Ordering::SeqCst) {
                return false;
            }
            q.push_back(env);
            self.ctl_len.fetch_add(1, Ordering::Release);
        }
        self.wake();
        true
    }
}

/// The consumer half of one receive lane, owned by exactly one reactor
/// thread. `try_recv` drains control messages first, then round-robins
/// over the registered producer rings — all plain atomic operations on
/// the steady state. Dropping the receiver closes the lane and drains
/// every queued envelope (slots complete empty), the torn-down-QP
/// analog.
pub struct LaneRx {
    lane: Arc<Lane>,
    rings: Vec<Arc<SpscRing<RpcEnvelope>>>,
    seen_version: u64,
    next: usize,
}

impl LaneRx {
    fn refresh(&mut self) {
        let v = self.lane.version.load(Ordering::Acquire);
        if v != self.seen_version {
            self.rings = self.lane.rings.lock().unwrap().clone(); // control-plane: snapshot refresh on connect
            self.seen_version = v;
        }
    }

    /// Dequeue the next inbound envelope, if any (non-blocking).
    pub fn try_recv(&mut self) -> Option<RpcEnvelope> {
        self.refresh();
        if self.lane.ctl_len.load(Ordering::Acquire) > 0 {
            let env = self.lane.ctl.lock().unwrap().pop_front(); // control-plane: one-shot message queue
            if let Some(env) = env {
                self.lane.ctl_len.fetch_sub(1, Ordering::Release);
                return Some(env);
            }
        }
        let n = self.rings.len();
        if n == 0 {
            return None;
        }
        for k in 0..n {
            let i = (self.next + k) % n;
            if let Some(env) = self.rings[i].pop() {
                self.next = (i + 1) % n;
                return Some(env);
            }
        }
        None
    }

    /// Is anything queued? (The reactor's pre-park re-check.)
    pub fn has_pending(&mut self) -> bool {
        self.refresh();
        self.lane.ctl_len.load(Ordering::Acquire) > 0
            || self.rings.iter().any(|r| !r.is_empty())
    }

    /// Blocking receive with a deadline — test and example servers; real
    /// reactors use [`Self::try_recv`] with their own idle parking.
    ///
    /// Parks on the lane's [`Waker`] instead of sleep-polling: producers
    /// ring the doorbell after publishing, so wake latency is bounded by
    /// the doorbell, not a sleep quantum, and an idle wait burns no
    /// scheduler ticks. The waker is installed lazily and bound to the
    /// calling thread — a `LaneRx` has exactly one consumer, so the
    /// caller *is* this lane's reactor.
    pub fn recv_timeout(&mut self, timeout: Duration) -> Option<RpcEnvelope> {
        let waker = self.lane.waker.get_or_init(|| Arc::new(Waker::new())).clone();
        waker.register_current();
        let deadline = std::time::Instant::now() + timeout;
        loop {
            if let Some(env) = self.try_recv() {
                return Some(env);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return None;
            }
            // Waker protocol: announce sleep, re-check the sources (the
            // lost-wakeup guard), park until doorbell or deadline.
            waker.begin_sleep();
            if self.has_pending() {
                waker.end_sleep();
                continue;
            }
            std::thread::park_timeout(deadline - now);
            waker.end_sleep();
        }
    }
}

impl Drop for LaneRx {
    fn drop(&mut self) {
        // Close first: producers that subsequently check `open` fail
        // fast (`send_ctl` checks under the queue lock; slot posters
        // self-complete via the wait loop's reclaim path).
        self.lane.open.store(false, Ordering::SeqCst);
        {
            let mut q = self.lane.ctl.lock().unwrap(); // control-plane: teardown drain
            self.lane.ctl_len.store(0, Ordering::Release);
            // Dropping envelopes drops reply senders (rpc callers see a
            // closed channel) and completes slot handles empty.
            q.clear();
        }
        let rings = self.lane.rings.lock().unwrap().clone(); // control-plane: teardown drain
        for r in &rings {
            while r.pop().is_some() {}
        }
    }
}

struct EndpointShared {
    regions: Vec<LoopbackRegion>,
    /// One receive lane per server shard.
    lanes: Vec<Arc<Lane>>,
}

/// Handle to all nodes (what a "connected QP mesh" gives you).
#[derive(Clone)]
pub struct LoopbackFabric {
    endpoints: Arc<Vec<EndpointShared>>,
}

impl LoopbackFabric {
    /// Build a fabric of `nodes` endpoints, each with the given region
    /// sizes registered and a single receive lane. Returns the fabric
    /// handle plus, per node, the receive lane its reactor drains.
    pub fn new(nodes: u32, region_sizes: &[usize]) -> (Self, Vec<LaneRx>) {
        let (fabric, rxs) = Self::new_sharded(nodes, region_sizes, 1);
        (fabric, rxs.into_iter().map(|mut lanes| lanes.remove(0)).collect())
    }

    /// Build a fabric whose endpoints each expose `lanes` receive lanes,
    /// so a node can run one reactor per shard. Returns per node the
    /// per-lane receivers.
    pub fn new_sharded(
        nodes: u32,
        region_sizes: &[usize],
        lanes: u32,
    ) -> (Self, Vec<Vec<LaneRx>>) {
        assert!(lanes >= 1, "at least one receive lane per endpoint");
        let mut shared = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..nodes {
            let regions: Vec<LoopbackRegion> =
                region_sizes.iter().map(|&l| LoopbackRegion::new(l)).collect();
            let mut node_lanes = Vec::new();
            let mut node_rxs = Vec::new();
            for _ in 0..lanes {
                let lane = Arc::new(Lane::new());
                node_rxs.push(LaneRx {
                    lane: lane.clone(),
                    rings: Vec::new(),
                    seen_version: 0,
                    next: 0,
                });
                node_lanes.push(lane);
            }
            shared.push(EndpointShared { regions, lanes: node_lanes });
            rxs.push(node_rxs);
        }
        (LoopbackFabric { endpoints: Arc::new(shared) }, rxs)
    }

    /// Install the reactor waker for `(node, lane)` — producers use it
    /// to unpark the draining thread after publishing work.
    pub fn set_lane_waker(&self, node: u32, lane: u32, waker: Arc<Waker>) {
        let _ = self.endpoints[node as usize].lanes[lane as usize].waker.set(waker);
    }

    /// One-sided read of `(region, offset, len)` on `node`. Allocates;
    /// prefer [`Self::read_into`] / [`Self::read_batch`] on hot paths.
    pub fn read(&self, node: u32, region: MrKey, offset: u64, len: u32) -> Vec<u8> {
        self.endpoints[node as usize].regions[region.0 as usize]
            .read(offset as usize, len as usize)
    }

    /// One-sided read into a caller-provided buffer (no allocation).
    pub fn read_into(&self, node: u32, region: MrKey, offset: u64, out: &mut [u8]) {
        self.endpoints[node as usize].regions[region.0 as usize]
            .read_into(offset as usize, out);
    }

    /// Doorbell-batched one-sided reads of `region` on `node`: one pass
    /// copies all `(offset, len)` requests into the caller-owned
    /// `scratch`; `f(i, bytes)` sees request `i`'s bytes in place. The
    /// caller reuses `scratch` across batches, so the steady state does
    /// not allocate.
    pub fn read_batch(
        &self,
        node: u32,
        region: MrKey,
        reqs: &[(u64, u32)],
        scratch: &mut Vec<u8>,
        f: impl FnMut(usize, &[u8]),
    ) {
        self.endpoints[node as usize].regions[region.0 as usize].read_many(reqs, scratch, f);
    }

    /// One-sided write to `(region, offset)` on `node`.
    pub fn write(&self, node: u32, region: MrKey, offset: u64, data: &[u8]) {
        self.endpoints[node as usize].regions[region.0 as usize].write(offset as usize, data);
    }

    /// Open a ring-buffer connection from `from` to `node`: `window`
    /// preallocated slots whose request/reply buffers reserve `slot_bytes`
    /// each, so steady-state RPC framing never allocates. Registers one
    /// producer ring on every lane of `node`; the returned connection is
    /// single-owner (`&mut` to post/harvest) and binds its completion
    /// wakeups to the calling thread — build it on the thread that will
    /// use it.
    pub fn connect(&self, from: u32, node: u32, window: usize, slot_bytes: usize) -> RingConn {
        assert!(window >= 1, "ring needs at least one slot");
        let waiter = std::thread::current();
        let slots: Vec<Arc<RingSlot>> = (0..window)
            .map(|_| {
                Arc::new(RingSlot {
                    from,
                    stage: AtomicU8::new(STAGE_FREE),
                    bufs: UnsafeCell::new(SlotBufs {
                        req: Vec::with_capacity(slot_bytes),
                        resp: Vec::with_capacity(slot_bytes),
                        imm: 0,
                    }),
                    waiter: waiter.clone(),
                })
            })
            .collect();
        let lanes = self.endpoints[node as usize]
            .lanes
            .iter()
            .map(|lane| LaneProducer { lane: lane.clone(), ring: lane.register(window) })
            .collect();
        RingConn { slots, free: (0..window).collect(), lane_of: vec![0; window], lanes }
    }

    /// Blocking one-shot RPC to `node` (lane 0): delivers `payload`,
    /// blocks for the handler's reply. Returns `None` when the remote
    /// reactor is gone. Allocates a channel per call — tests and control
    /// paths only; the dataplane uses [`RingConn`].
    pub fn rpc(&self, from: u32, node: u32, payload: Vec<u8>) -> Option<Vec<u8>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        let sent = self.endpoints[node as usize].lanes[0].send_ctl(RpcEnvelope::Message {
            from,
            payload,
            reply: Some(reply_tx),
        });
        if !sent {
            return None;
        }
        reply_rx.recv().ok()
    }

    /// Fire-and-forget message to lane 0 of a node's receive queue
    /// (control messages; no reply channel is allocated).
    pub fn send_raw(&self, from: u32, node: u32, payload: Vec<u8>) {
        self.send_raw_lane(from, node, 0, payload);
    }

    /// Fire-and-forget message to a specific lane of a node's receive
    /// queue.
    pub fn send_raw_lane(&self, from: u32, node: u32, lane: u32, payload: Vec<u8>) {
        let _ = self.endpoints[node as usize].lanes[lane as usize].send_ctl(
            RpcEnvelope::Message { from, payload, reply: None },
        );
    }

    /// Direct handle to a node's region (loading data in place).
    pub fn region(&self, node: u32, r: MrKey) -> LoopbackRegion {
        self.endpoints[node as usize].regions[r.0 as usize].clone()
    }

    /// Number of nodes.
    pub fn nodes(&self) -> u32 {
        self.endpoints.len() as u32
    }

    /// Receive lanes per endpoint.
    pub fn lanes(&self, node: u32) -> u32 {
        self.endpoints[node as usize].lanes.len() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    /// Generous deadline for test servers draining a lane.
    const TICK: Duration = Duration::from_secs(5);

    #[test]
    fn one_sided_read_write_roundtrip() {
        let (fabric, _rxs) = LoopbackFabric::new(2, &[4096]);
        fabric.write(1, MrKey(0), 100, b"storm");
        assert_eq!(&fabric.read(1, MrKey(0), 100, 5), b"storm");
        // Node 0's memory untouched.
        assert_eq!(fabric.read(0, MrKey(0), 100, 5), vec![0; 5]);
    }

    #[test]
    fn read_into_avoids_allocation() {
        let (fabric, _rxs) = LoopbackFabric::new(1, &[256]);
        fabric.write(0, MrKey(0), 32, b"ring");
        let mut buf = [0u8; 4];
        fabric.read_into(0, MrKey(0), 32, &mut buf);
        assert_eq!(&buf, b"ring");
    }

    #[test]
    fn read_batch_serves_all_requests_from_reused_scratch() {
        let (fabric, _rxs) = LoopbackFabric::new(1, &[256]);
        fabric.write(0, MrKey(0), 0, b"aa");
        fabric.write(0, MrKey(0), 10, b"bbb");
        fabric.write(0, MrKey(0), 20, b"c");
        let reqs = [(0u64, 2u32), (10, 3), (20, 1)];
        let mut scratch = Vec::new();
        let mut seen: Vec<Vec<u8>> = Vec::new();
        fabric.read_batch(0, MrKey(0), &reqs, &mut scratch, |i, bytes| {
            assert_eq!(i, seen.len());
            seen.push(bytes.to_vec());
        });
        assert_eq!(seen, vec![b"aa".to_vec(), b"bbb".to_vec(), b"c".to_vec()]);
        // The scratch holds the batch and is reused without reallocation
        // by an equal-or-smaller follow-up batch.
        let cap = scratch.capacity();
        assert!(cap >= 6);
        fabric.read_batch(0, MrKey(0), &reqs, &mut scratch, |_, _| {});
        assert_eq!(scratch.capacity(), cap, "steady-state batch reads must not reallocate");
    }

    #[test]
    fn spsc_ring_preserves_fifo_order() {
        let ring: SpscRing<u32> = SpscRing::new(8);
        assert!(ring.is_empty());
        for i in 0..8 {
            ring.push(i).unwrap();
        }
        assert!(ring.push(99).is_err(), "9th push into an 8-ring must refuse");
        for i in 0..8 {
            assert_eq!(ring.pop(), Some(i));
        }
        assert_eq!(ring.pop(), None);
    }

    #[test]
    fn rpc_roundtrip_through_handler() {
        let (fabric, mut rxs) = LoopbackFabric::new(2, &[64]);
        let mut rx = rxs.remove(1);
        let h = thread::spawn(move || {
            // Serve exactly one request, echo reversed.
            match rx.recv_timeout(TICK).expect("request arrives") {
                RpcEnvelope::Message { payload, reply, .. } => {
                    let mut out = payload.clone();
                    out.reverse();
                    reply.unwrap().send(out).unwrap();
                }
                RpcEnvelope::Slot(_) => panic!("expected one-shot message"),
            }
        });
        let resp = fabric.rpc(0, 1, vec![1, 2, 3]).unwrap();
        assert_eq!(resp, vec![3, 2, 1]);
        h.join().unwrap();
    }

    #[test]
    fn concurrent_rpcs_all_answered() {
        let (fabric, mut rxs) = LoopbackFabric::new(2, &[64]);
        let mut rx = rxs.remove(1);
        let server = thread::spawn(move || {
            let mut served = 0;
            while served < 64 {
                match rx.recv_timeout(TICK).expect("request arrives") {
                    RpcEnvelope::Message { payload, reply, .. } => {
                        reply.unwrap().send(payload).unwrap();
                    }
                    RpcEnvelope::Slot(_) => panic!("expected one-shot message"),
                }
                served += 1;
            }
        });
        let mut handles = Vec::new();
        for i in 0..64u8 {
            let f = fabric.clone();
            handles.push(thread::spawn(move || f.rpc(0, 1, vec![i]).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), vec![i as u8]);
        }
        server.join().unwrap();
    }

    #[test]
    fn rpc_to_dead_node_returns_none() {
        let (fabric, rxs) = LoopbackFabric::new(2, &[64]);
        drop(rxs); // no reactors: lanes closed
        assert_eq!(fabric.rpc(0, 1, vec![1]), None);
    }

    #[test]
    fn ring_window_of_outstanding_rpcs_completes() {
        let (fabric, mut rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let mut rx = rxs.remove(1).remove(0);
        let server = thread::spawn(move || {
            let mut served = 0;
            while served < 8 {
                match rx.recv_timeout(TICK).expect("slot arrives") {
                    RpcEnvelope::Slot(slot) => {
                        assert_eq!(slot.from(), 0);
                        slot.serve(|req, out| {
                            out.extend_from_slice(req);
                            out.reverse();
                        });
                    }
                    RpcEnvelope::Message { .. } => panic!("expected slot"),
                }
                served += 1;
            }
        });
        let mut conn = fabric.connect(0, 1, 8, 64);
        // Fill the whole window before harvesting anything.
        let toks: Vec<SlotToken> =
            (0..8u8).map(|i| conn.post(0, |buf| buf.extend_from_slice(&[i, i + 1]))).collect();
        for (i, tok) in toks.into_iter().enumerate() {
            let i = i as u8;
            let reply = conn.take_reply(tok, |b| b.to_vec());
            assert_eq!(reply, vec![i + 1, i]);
        }
        server.join().unwrap();
    }

    #[test]
    fn ring_immediate_travels_with_the_slot() {
        let (fabric, mut rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let mut rx = rxs.remove(1).remove(0);
        let server = thread::spawn(move || {
            let mut imms = Vec::new();
            for _ in 0..3 {
                match rx.recv_timeout(TICK).expect("slot arrives") {
                    RpcEnvelope::Slot(slot) => {
                        imms.push(slot.imm());
                        slot.serve(|req, out| out.extend_from_slice(req));
                    }
                    RpcEnvelope::Message { .. } => panic!("expected slot"),
                }
            }
            imms
        });
        let mut conn = fabric.connect(0, 1, 4, 64);
        let toks: Vec<SlotToken> = [0xA0u32, 0xB1, 0xC2]
            .iter()
            .map(|&imm| conn.post_imm(0, imm, |b| b.push(imm as u8)))
            .collect();
        for tok in toks {
            conn.take_reply(tok, |_| ());
        }
        assert_eq!(server.join().unwrap(), vec![0xA0, 0xB1, 0xC2]);
        // Plain post carries immediate 0.
        let (fabric2, mut rxs2) = LoopbackFabric::new_sharded(2, &[64], 1);
        let mut rx2 = rxs2.remove(1).remove(0);
        let h = thread::spawn(move || match rx2.recv_timeout(TICK).expect("slot arrives") {
            RpcEnvelope::Slot(slot) => {
                let imm = slot.imm();
                slot.serve(|_, out| out.push(1));
                imm
            }
            RpcEnvelope::Message { .. } => panic!("expected slot"),
        });
        let mut conn2 = fabric2.connect(0, 1, 1, 64);
        let tok = conn2.post(0, |b| b.push(9));
        conn2.take_reply(tok, |_| ());
        assert_eq!(h.join().unwrap(), 0);
    }

    #[test]
    fn slot_request_is_peekable_before_serving() {
        let (fabric, mut rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let mut rx = rxs.remove(1).remove(0);
        let server = thread::spawn(move || match rx.recv_timeout(TICK).expect("slot arrives") {
            RpcEnvelope::Slot(slot) => {
                // Routing peek: observe the request without serving it.
                let first = slot.peek(|req| req[0]);
                slot.serve(|req, out| out.extend_from_slice(req));
                first
            }
            RpcEnvelope::Message { .. } => panic!("expected slot"),
        });
        let mut conn = fabric.connect(0, 1, 1, 64);
        let tok = conn.post(0, |b| b.extend_from_slice(&[42, 7]));
        conn.take_reply(tok, |b| assert_eq!(b, &[42, 7][..]));
        assert_eq!(server.join().unwrap(), 42);
    }

    #[test]
    fn dropped_server_completes_slot_with_empty_reply() {
        let (fabric, rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let mut conn = fabric.connect(0, 1, 2, 64);
        let tok = conn.post(0, |b| b.extend_from_slice(b"hi"));
        // The reactor exits with the request still queued: the teardown
        // drain drops the envelope's slot handle unserved.
        drop(rxs);
        let reply_len = conn.take_reply(tok, |b| b.len());
        assert_eq!(reply_len, 0, "unserved slot must complete empty, not hang");
        // Posts after teardown fail fast the same way.
        let tok = conn.post(0, |b| b.extend_from_slice(b"again"));
        assert_eq!(conn.take_reply(tok, |b| b.len()), 0);
    }

    #[test]
    fn ring_slot_buffers_are_reused_without_reallocation() {
        let (fabric, mut rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let mut rx = rxs.remove(1).remove(0);
        let server = thread::spawn(move || {
            for _ in 0..16 {
                match rx.recv_timeout(TICK).expect("slot arrives") {
                    RpcEnvelope::Slot(slot) => slot.serve(|req, out| out.extend_from_slice(req)),
                    RpcEnvelope::Message { .. } => panic!("expected slot"),
                }
            }
        });
        // Window of 1: the same slot serves every request.
        let mut conn = fabric.connect(0, 1, 1, 128);
        for round in 0..16u8 {
            let tok = conn.post(0, |buf| {
                assert!(buf.capacity() >= 128, "slot buffer must stay preallocated");
                buf.extend_from_slice(&[round; 32]);
            });
            conn.take_reply(tok, |b| assert_eq!(b, &[round; 32][..]));
        }
        server.join().unwrap();
    }
}
