//! Live in-process fabric: RDMA-like primitives over shared memory +
//! threads.
//!
//! Used by the end-to-end examples: the same Storm dataplane logic that the
//! simulator drives (sans-io transaction engine, MICA table, callback API)
//! runs here against *real* memory and *real* threads, in wall-clock time,
//! with the PJRT batch-hash engine on the lookup path.
//!
//! Semantics mirror the verbs we model:
//! * `read` / `read_into` / `read_batch` — one-sided: no code runs on the
//!   remote node's event loop, just a direct memory copy (an RDMA READ
//!   against registered memory). `read_batch` is the doorbell-batched
//!   variant: one region acquisition covers a whole group of reads, the
//!   way one doorbell ring posts a chain of work requests.
//! * ring RPCs ([`RingConn`]) — write-with-immediate style messaging into
//!   **preallocated ring-buffer slots**: `post` frames the request
//!   directly into a reusable slot buffer (no per-call allocation), the
//!   remote event loop runs the handler and writes the reply into the
//!   same slot's reply buffer, and the caller harvests it with
//!   `poll`/`take_reply`. A client keeps a *window* of outstanding
//!   requests this way; a full ring blocks the poster (RC backpressure,
//!   not UD drops).
//! * `rpc` — legacy blocking convenience over a one-shot channel (tests,
//!   control paths). The dataplane hot path uses ring slots.
//!
//! Each endpoint exposes one receive queue per *lane*; the live cluster
//! runs one server loop per lane so bucket-range shards drain their own
//! queues in parallel (the paper's per-thread QP + CQ layout).

use std::sync::mpsc::{sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Condvar, Mutex, RwLock};

use crate::mem::MrKey;

/// A registered memory region on a loopback node.
#[derive(Clone)]
pub struct LoopbackRegion {
    bytes: Arc<RwLock<Vec<u8>>>,
}

impl LoopbackRegion {
    /// Region of `len` zero bytes.
    pub fn new(len: usize) -> Self {
        LoopbackRegion { bytes: Arc::new(RwLock::new(vec![0; len])) }
    }

    /// One-sided read (no remote CPU). Allocates; prefer [`Self::read_into`]
    /// on hot paths.
    pub fn read(&self, offset: usize, len: usize) -> Vec<u8> {
        let g = self.bytes.read().unwrap();
        g[offset..offset + len].to_vec()
    }

    /// One-sided read into a caller-provided buffer (no allocation).
    pub fn read_into(&self, offset: usize, out: &mut [u8]) {
        let g = self.bytes.read().unwrap();
        out.copy_from_slice(&g[offset..offset + out.len()]);
    }

    /// Doorbell-batched one-sided reads: a single region acquisition
    /// serves every `(offset, len)` request; `f(i, bytes)` observes the
    /// bytes of request `i` in place (zero copy).
    pub fn read_many(&self, reqs: &[(u64, u32)], mut f: impl FnMut(usize, &[u8])) {
        let g = self.bytes.read().unwrap();
        for (i, &(offset, len)) in reqs.iter().enumerate() {
            let offset = offset as usize;
            f(i, &g[offset..offset + len as usize]);
        }
    }

    /// One-sided write (no remote CPU).
    pub fn write(&self, offset: usize, data: &[u8]) {
        let mut g = self.bytes.write().unwrap();
        g[offset..offset + data.len()].copy_from_slice(data);
    }

    /// Region length.
    pub fn len(&self) -> usize {
        self.bytes.read().unwrap().len()
    }

    /// True when zero-length.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Where a ring slot is in its post → serve → harvest cycle.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SlotStage {
    /// Owned by the client, available for the next `post`.
    Free,
    /// Request framed into `req`, awaiting the remote handler.
    Posted,
    /// Reply written into `resp`, awaiting `take_reply`.
    Done,
}

struct SlotInner {
    stage: SlotStage,
    /// Request bytes, framed in place by the poster.
    req: Vec<u8>,
    /// Reply bytes, written in place by the server.
    resp: Vec<u8>,
    /// 32-bit immediate attached by the poster (`rdma_write_with_imm`'s
    /// immediate value): carries the poster's correlation cookie to the
    /// responder without parsing the payload.
    imm: u32,
}

/// One preallocated ring-buffer slot of a [`RingConn`]: the request and
/// reply buffers are reused across RPCs, so steady-state messaging does
/// not allocate.
pub struct RingSlot {
    /// Sender node id (constant for the connection).
    from: u32,
    inner: Mutex<SlotInner>,
    done: Condvar,
}

impl RingSlot {
    fn complete_empty(&self) {
        let mut g = self.inner.lock().unwrap();
        if g.stage == SlotStage::Posted {
            g.resp.clear();
            g.stage = SlotStage::Done;
            drop(g);
            self.done.notify_all();
        }
    }
}

/// The server's owning handle to one posted ring slot. Dropping it
/// unserved (e.g. an event loop exiting with requests still queued)
/// completes the slot with an **empty reply**, so the posting client
/// observes a decode failure instead of blocking forever on the slot.
pub struct SlotHandle(Arc<RingSlot>);

impl SlotHandle {
    /// Sender node id.
    pub fn from(&self) -> u32 {
        self.0.from
    }

    /// Immediate value the poster attached (see [`RingConn::post_imm`]).
    pub fn imm(&self) -> u32 {
        self.0.inner.lock().unwrap().imm
    }

    /// Run `f(request_bytes, reply_buffer)` and complete the slot. The
    /// reply buffer is cleared first; `f` frames the response directly
    /// into it. The slot's buffers are swapped out for the duration of
    /// `f` (no allocation), so the poster's `poll` calls stay cheap while
    /// the handler runs.
    pub fn serve(&self, f: impl FnOnce(&[u8], &mut Vec<u8>)) {
        let slot = &*self.0;
        let (req, mut resp) = {
            let mut g = slot.inner.lock().unwrap();
            (std::mem::take(&mut g.req), std::mem::take(&mut g.resp))
        };
        resp.clear();
        f(&req, &mut resp);
        {
            let mut g = slot.inner.lock().unwrap();
            g.req = req;
            g.resp = resp;
            g.stage = SlotStage::Done;
        }
        slot.done.notify_all();
    }
}

impl Drop for SlotHandle {
    fn drop(&mut self) {
        self.0.complete_empty();
    }
}

/// Handle to an outstanding ring RPC (an index into the connection's
/// slot ring).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SlotToken(usize);

/// A client's ring-buffer connection to one server node: a fixed window
/// of reusable request/reply slots (the paper's preallocated per-sender
/// ring at the receiver). Clone-free; share behind an `Arc` if several
/// threads must post on the same ring.
pub struct RingConn {
    fabric: LoopbackFabric,
    node: u32,
    slots: Vec<Arc<RingSlot>>,
    free: Mutex<Vec<usize>>,
    freed: Condvar,
}

impl RingConn {
    /// Number of slots (the maximum outstanding window).
    pub fn window(&self) -> usize {
        self.slots.len()
    }

    /// Post a request on `lane`, framing it directly into a free slot's
    /// request buffer via `fill`. **Blocks while the ring is full** (every
    /// slot outstanding) until `take_reply` frees one — backpressure, not
    /// drops. Returns a token to poll/harvest the reply with.
    pub fn post(&self, lane: u32, fill: impl FnOnce(&mut Vec<u8>)) -> SlotToken {
        self.post_imm(lane, 0, fill)
    }

    /// [`Self::post`] with a 32-bit immediate: the write-with-immediate
    /// value the responder observes alongside the slot (correlation
    /// cookies for multiplexed posters).
    pub fn post_imm(&self, lane: u32, imm: u32, fill: impl FnOnce(&mut Vec<u8>)) -> SlotToken {
        let idx = {
            let mut free = self.free.lock().unwrap();
            loop {
                if let Some(i) = free.pop() {
                    break i;
                }
                free = self.freed.wait(free).unwrap();
            }
        };
        self.submit(idx, lane, imm, fill);
        SlotToken(idx)
    }

    /// Non-blocking [`Self::post`]: `None` when the ring is full.
    pub fn try_post(&self, lane: u32, fill: impl FnOnce(&mut Vec<u8>)) -> Option<SlotToken> {
        self.try_post_imm(lane, 0, fill)
    }

    /// Non-blocking [`Self::post_imm`]: `None` when the ring is full.
    /// Posters that must never block (a scheduler that also harvests the
    /// replies on the same thread would deadlock a full ring) queue on
    /// `None` and retry after harvesting.
    pub fn try_post_imm(
        &self,
        lane: u32,
        imm: u32,
        fill: impl FnOnce(&mut Vec<u8>),
    ) -> Option<SlotToken> {
        let idx = self.free.lock().unwrap().pop()?;
        self.submit(idx, lane, imm, fill);
        Some(SlotToken(idx))
    }

    fn submit(&self, idx: usize, lane: u32, imm: u32, fill: impl FnOnce(&mut Vec<u8>)) {
        let slot = &self.slots[idx];
        {
            let mut g = slot.inner.lock().unwrap();
            g.req.clear();
            fill(&mut g.req);
            g.imm = imm;
            g.stage = SlotStage::Posted;
        }
        self.fabric.endpoints[self.node as usize].lanes[lane as usize]
            .send(RpcEnvelope::Slot(SlotHandle(slot.clone())))
            .expect("loopback endpoint event loop gone");
    }

    /// Has the reply for `tok` arrived? (Non-blocking completion poll.)
    pub fn poll(&self, tok: SlotToken) -> bool {
        self.slots[tok.0].inner.lock().unwrap().stage == SlotStage::Done
    }

    /// Block until the reply for `tok` has arrived (does not free the
    /// slot; follow with [`Self::take_reply`]).
    pub fn wait(&self, tok: SlotToken) {
        let slot = &self.slots[tok.0];
        let mut g = slot.inner.lock().unwrap();
        while g.stage != SlotStage::Done {
            g = slot.done.wait(g).unwrap();
        }
    }

    /// Wait for the reply to `tok`, observe its bytes in place via `f`,
    /// and return the slot to the free ring.
    pub fn take_reply<R>(&self, tok: SlotToken, f: impl FnOnce(&[u8]) -> R) -> R {
        let slot = &self.slots[tok.0];
        let r = {
            let mut g = slot.inner.lock().unwrap();
            while g.stage != SlotStage::Done {
                g = slot.done.wait(g).unwrap();
            }
            let r = f(&g.resp);
            g.stage = SlotStage::Free;
            r
        };
        self.free.lock().unwrap().push(tok.0);
        self.freed.notify_one();
        r
    }
}

/// An inbound message on a node's receive queue.
pub enum RpcEnvelope {
    /// One-shot message (legacy `rpc`, control traffic). `reply` is `None`
    /// for fire-and-forget sends — no throwaway channel is allocated.
    Message {
        /// Sender node id.
        from: u32,
        /// Request payload (header + body, see [`crate::dataplane::rpc`]).
        payload: Vec<u8>,
        /// Reply channel, when the sender blocks for a response.
        reply: Option<Sender<Vec<u8>>>,
    },
    /// Ring-slot request: the payload sits in the slot's request buffer
    /// and the handler writes the reply back into the same slot.
    Slot(SlotHandle),
}

struct EndpointShared {
    regions: Vec<LoopbackRegion>,
    /// One receive queue per lane (per-shard server loop).
    lanes: Vec<SyncSender<RpcEnvelope>>,
}

/// Handle to all nodes (what a "connected QP mesh" gives you).
#[derive(Clone)]
pub struct LoopbackFabric {
    endpoints: Arc<Vec<EndpointShared>>,
}

impl LoopbackFabric {
    /// Build a fabric of `nodes` endpoints, each with the given region
    /// sizes registered and a single receive lane. Returns the fabric
    /// handle plus, per node, the RPC receive queue its event loop drains.
    pub fn new(nodes: u32, region_sizes: &[usize]) -> (Self, Vec<Receiver<RpcEnvelope>>) {
        let (fabric, rxs) = Self::new_sharded(nodes, region_sizes, 1);
        (fabric, rxs.into_iter().map(|mut lanes| lanes.remove(0)).collect())
    }

    /// Build a fabric whose endpoints each expose `lanes` receive queues,
    /// so a node can run one server loop per bucket-range shard. Returns
    /// per node the per-lane receivers.
    pub fn new_sharded(
        nodes: u32,
        region_sizes: &[usize],
        lanes: u32,
    ) -> (Self, Vec<Vec<Receiver<RpcEnvelope>>>) {
        assert!(lanes >= 1, "at least one receive lane per endpoint");
        let mut shared = Vec::new();
        let mut rxs = Vec::new();
        for _ in 0..nodes {
            let regions: Vec<LoopbackRegion> =
                region_sizes.iter().map(|&l| LoopbackRegion::new(l)).collect();
            // Bounded like a receive queue: senders block when the RQ is
            // full (RC write-with-imm backpressure, not UD drops).
            let mut txs = Vec::new();
            let mut node_rxs = Vec::new();
            for _ in 0..lanes {
                let (tx, rx) = sync_channel(4096);
                txs.push(tx);
                node_rxs.push(rx);
            }
            shared.push(EndpointShared { regions, lanes: txs });
            rxs.push(node_rxs);
        }
        (LoopbackFabric { endpoints: Arc::new(shared) }, rxs)
    }

    /// One-sided read of `(region, offset, len)` on `node`. Allocates;
    /// prefer [`Self::read_into`] / [`Self::read_batch`] on hot paths.
    pub fn read(&self, node: u32, region: MrKey, offset: u64, len: u32) -> Vec<u8> {
        self.endpoints[node as usize].regions[region.0 as usize]
            .read(offset as usize, len as usize)
    }

    /// One-sided read into a caller-provided buffer (no allocation).
    pub fn read_into(&self, node: u32, region: MrKey, offset: u64, out: &mut [u8]) {
        self.endpoints[node as usize].regions[region.0 as usize]
            .read_into(offset as usize, out);
    }

    /// Doorbell-batched one-sided reads of `region` on `node`: one region
    /// acquisition serves all `(offset, len)` requests; `f(i, bytes)` sees
    /// request `i`'s bytes in place.
    pub fn read_batch(
        &self,
        node: u32,
        region: MrKey,
        reqs: &[(u64, u32)],
        f: impl FnMut(usize, &[u8]),
    ) {
        self.endpoints[node as usize].regions[region.0 as usize].read_many(reqs, f);
    }

    /// One-sided write to `(region, offset)` on `node`.
    pub fn write(&self, node: u32, region: MrKey, offset: u64, data: &[u8]) {
        self.endpoints[node as usize].regions[region.0 as usize].write(offset as usize, data);
    }

    /// Open a ring-buffer connection from `from` to `node`: `window`
    /// preallocated slots whose request/reply buffers reserve `slot_bytes`
    /// each, so steady-state RPC framing never allocates.
    pub fn connect(&self, from: u32, node: u32, window: usize, slot_bytes: usize) -> RingConn {
        assert!(window >= 1, "ring needs at least one slot");
        let slots = (0..window)
            .map(|_| {
                Arc::new(RingSlot {
                    from,
                    inner: Mutex::new(SlotInner {
                        stage: SlotStage::Free,
                        req: Vec::with_capacity(slot_bytes),
                        resp: Vec::with_capacity(slot_bytes),
                        imm: 0,
                    }),
                    done: Condvar::new(),
                })
            })
            .collect();
        RingConn {
            fabric: self.clone(),
            node,
            slots,
            free: Mutex::new((0..window).collect()),
            freed: Condvar::new(),
        }
    }

    /// Blocking one-shot RPC to `node` (lane 0): delivers `payload`,
    /// blocks for the handler's reply. Returns `None` when the remote
    /// event loop is gone. Allocates a channel per call — tests and
    /// control paths only; the dataplane uses [`RingConn`].
    pub fn rpc(&self, from: u32, node: u32, payload: Vec<u8>) -> Option<Vec<u8>> {
        let (reply_tx, reply_rx) = std::sync::mpsc::channel();
        self.endpoints[node as usize].lanes[0]
            .send(RpcEnvelope::Message { from, payload, reply: Some(reply_tx) })
            .ok()?;
        reply_rx.recv().ok()
    }

    /// Fire-and-forget message to lane 0 of a node's RPC queue (control
    /// messages; no reply channel is allocated).
    pub fn send_raw(&self, from: u32, node: u32, payload: Vec<u8>) {
        self.send_raw_lane(from, node, 0, payload);
    }

    /// Fire-and-forget message to a specific lane of a node's RPC queue.
    pub fn send_raw_lane(&self, from: u32, node: u32, lane: u32, payload: Vec<u8>) {
        let _ = self.endpoints[node as usize].lanes[lane as usize].send(RpcEnvelope::Message {
            from,
            payload,
            reply: None,
        });
    }

    /// Direct handle to a node's region (loading data in place).
    pub fn region(&self, node: u32, r: MrKey) -> LoopbackRegion {
        self.endpoints[node as usize].regions[r.0 as usize].clone()
    }

    /// Number of nodes.
    pub fn nodes(&self) -> u32 {
        self.endpoints.len() as u32
    }

    /// Receive lanes per endpoint.
    pub fn lanes(&self, node: u32) -> u32 {
        self.endpoints[node as usize].lanes.len() as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn one_sided_read_write_roundtrip() {
        let (fabric, _rxs) = LoopbackFabric::new(2, &[4096]);
        fabric.write(1, MrKey(0), 100, b"storm");
        assert_eq!(&fabric.read(1, MrKey(0), 100, 5), b"storm");
        // Node 0's memory untouched.
        assert_eq!(fabric.read(0, MrKey(0), 100, 5), vec![0; 5]);
    }

    #[test]
    fn read_into_avoids_allocation() {
        let (fabric, _rxs) = LoopbackFabric::new(1, &[256]);
        fabric.write(0, MrKey(0), 32, b"ring");
        let mut buf = [0u8; 4];
        fabric.read_into(0, MrKey(0), 32, &mut buf);
        assert_eq!(&buf, b"ring");
    }

    #[test]
    fn read_batch_serves_all_requests_in_place() {
        let (fabric, _rxs) = LoopbackFabric::new(1, &[256]);
        fabric.write(0, MrKey(0), 0, b"aa");
        fabric.write(0, MrKey(0), 10, b"bbb");
        fabric.write(0, MrKey(0), 20, b"c");
        let reqs = [(0u64, 2u32), (10, 3), (20, 1)];
        let mut seen: Vec<Vec<u8>> = Vec::new();
        fabric.read_batch(0, MrKey(0), &reqs, |i, bytes| {
            assert_eq!(i, seen.len());
            seen.push(bytes.to_vec());
        });
        assert_eq!(seen, vec![b"aa".to_vec(), b"bbb".to_vec(), b"c".to_vec()]);
    }

    #[test]
    fn rpc_roundtrip_through_handler() {
        let (fabric, mut rxs) = LoopbackFabric::new(2, &[64]);
        let rx = rxs.remove(1);
        let h = thread::spawn(move || {
            // Serve exactly one request, echo reversed.
            match rx.recv().unwrap() {
                RpcEnvelope::Message { payload, reply, .. } => {
                    let mut out = payload.clone();
                    out.reverse();
                    reply.unwrap().send(out).unwrap();
                }
                RpcEnvelope::Slot(_) => panic!("expected one-shot message"),
            }
        });
        let resp = fabric.rpc(0, 1, vec![1, 2, 3]).unwrap();
        assert_eq!(resp, vec![3, 2, 1]);
        h.join().unwrap();
    }

    #[test]
    fn concurrent_rpcs_all_answered() {
        let (fabric, mut rxs) = LoopbackFabric::new(2, &[64]);
        let rx = rxs.remove(1);
        let server = thread::spawn(move || {
            let mut served = 0;
            while served < 64 {
                match rx.recv().unwrap() {
                    RpcEnvelope::Message { payload, reply, .. } => {
                        reply.unwrap().send(payload).unwrap();
                    }
                    RpcEnvelope::Slot(_) => panic!("expected one-shot message"),
                }
                served += 1;
            }
        });
        let mut handles = Vec::new();
        for i in 0..64u8 {
            let f = fabric.clone();
            handles.push(thread::spawn(move || f.rpc(0, 1, vec![i]).unwrap()));
        }
        for (i, h) in handles.into_iter().enumerate() {
            assert_eq!(h.join().unwrap(), vec![i as u8]);
        }
        server.join().unwrap();
    }

    #[test]
    fn rpc_to_dead_node_returns_none() {
        let (fabric, rxs) = LoopbackFabric::new(2, &[64]);
        drop(rxs); // no event loops
        assert_eq!(fabric.rpc(0, 1, vec![1]), None);
    }

    #[test]
    fn ring_window_of_outstanding_rpcs_completes() {
        let (fabric, mut rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let rx = rxs.remove(1).remove(0);
        let server = thread::spawn(move || {
            let mut served = 0;
            while served < 8 {
                match rx.recv().unwrap() {
                    RpcEnvelope::Slot(slot) => {
                        assert_eq!(slot.from(), 0);
                        slot.serve(|req, out| {
                            out.extend_from_slice(req);
                            out.reverse();
                        });
                    }
                    RpcEnvelope::Message { .. } => panic!("expected slot"),
                }
                served += 1;
            }
        });
        let conn = fabric.connect(0, 1, 8, 64);
        // Fill the whole window before harvesting anything.
        let toks: Vec<SlotToken> =
            (0..8u8).map(|i| conn.post(0, |buf| buf.extend_from_slice(&[i, i + 1]))).collect();
        for (i, tok) in toks.into_iter().enumerate() {
            let i = i as u8;
            let reply = conn.take_reply(tok, |b| b.to_vec());
            assert_eq!(reply, vec![i + 1, i]);
        }
        server.join().unwrap();
    }

    #[test]
    fn ring_immediate_travels_with_the_slot() {
        let (fabric, mut rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let rx = rxs.remove(1).remove(0);
        let server = thread::spawn(move || {
            let mut imms = Vec::new();
            for _ in 0..3 {
                match rx.recv().unwrap() {
                    RpcEnvelope::Slot(slot) => {
                        imms.push(slot.imm());
                        slot.serve(|req, out| out.extend_from_slice(req));
                    }
                    RpcEnvelope::Message { .. } => panic!("expected slot"),
                }
            }
            imms
        });
        let conn = fabric.connect(0, 1, 4, 64);
        let toks: Vec<SlotToken> = [0xA0u32, 0xB1, 0xC2]
            .iter()
            .map(|&imm| conn.post_imm(0, imm, |b| b.push(imm as u8)))
            .collect();
        for tok in toks {
            conn.take_reply(tok, |_| ());
        }
        assert_eq!(server.join().unwrap(), vec![0xA0, 0xB1, 0xC2]);
        // Plain post carries immediate 0.
        let (fabric2, mut rxs2) = LoopbackFabric::new_sharded(2, &[64], 1);
        let rx2 = rxs2.remove(1).remove(0);
        let h = thread::spawn(move || match rx2.recv().unwrap() {
            RpcEnvelope::Slot(slot) => {
                let imm = slot.imm();
                slot.serve(|_, out| out.push(1));
                imm
            }
            RpcEnvelope::Message { .. } => panic!("expected slot"),
        });
        let conn2 = fabric2.connect(0, 1, 1, 64);
        let tok = conn2.post(0, |b| b.push(9));
        conn2.take_reply(tok, |_| ());
        assert_eq!(h.join().unwrap(), 0);
    }

    #[test]
    fn dropped_server_completes_slot_with_empty_reply() {
        let (fabric, rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let conn = fabric.connect(0, 1, 2, 64);
        let tok = conn.post(0, |b| b.extend_from_slice(b"hi"));
        // Server loops exit with the request still queued: the envelope's
        // slot handle is dropped unserved.
        drop(rxs);
        let reply_len = conn.take_reply(tok, |b| b.len());
        assert_eq!(reply_len, 0, "unserved slot must complete empty, not hang");
    }

    #[test]
    fn ring_slot_buffers_are_reused_without_reallocation() {
        let (fabric, mut rxs) = LoopbackFabric::new_sharded(2, &[64], 1);
        let rx = rxs.remove(1).remove(0);
        let server = thread::spawn(move || {
            for _ in 0..16 {
                match rx.recv().unwrap() {
                    RpcEnvelope::Slot(slot) => slot.serve(|req, out| out.extend_from_slice(req)),
                    RpcEnvelope::Message { .. } => panic!("expected slot"),
                }
            }
        });
        // Window of 1: the same slot serves every request.
        let conn = fabric.connect(0, 1, 1, 128);
        for round in 0..16u8 {
            let tok = conn.post(0, |buf| {
                assert!(buf.capacity() >= 128, "slot buffer must stay preallocated");
                buf.extend_from_slice(&[round; 32]);
            });
            conn.take_reply(tok, |b| assert_eq!(b, &[round; 32][..]));
        }
        server.join().unwrap();
    }
}
