//! Thread-to-core pinning for shard reactors.
//!
//! The shared-nothing dataplane wants each shard reactor on its own
//! core: no migration-induced cache churn, no two reactors time-slicing
//! one CPU while another sits idle. The container image carries no
//! `libc` crate, so on Linux we issue the raw `sched_setaffinity`
//! syscall directly; everywhere else (or if the sandbox denies the
//! call) pinning degrades to a no-op and the reactor runs wherever the
//! scheduler puts it — correctness never depends on placement.

/// Best-effort pin of the calling thread to `core` (modulo the
/// machine's CPU count — callers pass a dense shard index). Returns
/// `true` when the kernel accepted the mask.
pub fn pin_to_core(core: usize) -> bool {
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    set_affinity_mask(1u64 << ((core % cpus) % 64))
}

/// Number of CPUs visible to this process (the scaling curve's natural
/// ceiling).
pub fn online_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
fn set_affinity_mask(mask: u64) -> bool {
    // sched_setaffinity(pid=0 /* calling thread */, len=8, &mask)
    let ret: i64;
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203i64 => ret,
            in("rdi") 0i64,
            in("rsi") 8usize,
            in("rdx") &mask as *const u64,
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
fn set_affinity_mask(mask: u64) -> bool {
    let ret: i64;
    unsafe {
        std::arch::asm!(
            "svc 0",
            in("x8") 122i64, // sched_setaffinity
            inlateout("x0") 0i64 => ret,
            in("x1") 8usize,
            in("x2") &mask as *const u64,
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn set_affinity_mask(_mask: u64) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pinning_is_best_effort() {
        // Must not crash whether or not the platform/sandbox allows it.
        let _ = pin_to_core(0);
        assert!(online_cpus() >= 1);
    }
}
