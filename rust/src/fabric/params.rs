//! Wire-level fabric parameters.
//!
//! Calibrated so that the composed unloaded paths reproduce Table 5 of the
//! paper (CX4): one-sided read RTT 1.8 µs on IB EDR / 2.8 µs on RoCE, with
//! the RPC, FaRM and LITE numbers following from the same constants plus
//! the per-system path differences.



/// Fabric technology.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FabricKind {
    /// InfiniBand EDR, 100 Gbps (the 32-node evaluation cluster).
    IbEdr,
    /// RoCE v2 at 100 Gbps (the CX4/CX5 pairs).
    Roce100,
    /// RoCE v2 at 40 Gbps (the CX3 pair).
    Roce40,
}

/// Wire parameters for one fabric.
#[derive(Clone, Copy, Debug)]
pub struct FabricParams {
    /// One-way propagation + switching latency for a minimal packet (ns).
    pub base_one_way_ns: u64,
    /// Link bandwidth in Gbps.
    pub gbps: f64,
    /// Per-byte host-DMA/wire overlap factor: fraction of payload
    /// serialization that is *not* hidden by cut-through pipelining.
    pub store_and_forward: f64,
}

impl FabricKind {
    /// Parameter set for this fabric.
    pub fn params(self) -> FabricParams {
        match self {
            FabricKind::IbEdr => {
                FabricParams { base_one_way_ns: 410, gbps: 100.0, store_and_forward: 0.5 }
            }
            FabricKind::Roce100 => {
                FabricParams { base_one_way_ns: 910, gbps: 100.0, store_and_forward: 0.5 }
            }
            FabricKind::Roce40 => {
                FabricParams { base_one_way_ns: 1000, gbps: 40.0, store_and_forward: 0.5 }
            }
        }
    }
}

impl FabricParams {
    /// One-way wire time for a `bytes`-sized transfer (ns).
    pub fn one_way_ns(&self, bytes: u32) -> u64 {
        let ser = bytes as f64 * 8.0 / self.gbps * self.store_and_forward;
        self.base_one_way_ns + ser.round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ib_faster_than_roce() {
        let ib = FabricKind::IbEdr.params();
        let roce = FabricKind::Roce100.params();
        assert!(ib.one_way_ns(128) < roce.one_way_ns(128));
        // Table 5: RoCE adds ~1 us to the RR round trip => ~500 ns one-way.
        let delta = roce.one_way_ns(128) - ib.one_way_ns(128);
        assert!((400..=600).contains(&delta), "delta {delta}");
    }

    #[test]
    fn serialization_grows_with_size() {
        let ib = FabricKind::IbEdr.params();
        assert!(ib.one_way_ns(1024) > ib.one_way_ns(64));
        // 1 KB at 100 Gbps = 82 ns serialization; half visible.
        assert_eq!(ib.one_way_ns(1024) - ib.base_one_way_ns, 41);
    }

    #[test]
    fn forty_gig_serializes_slower() {
        let r40 = FabricKind::Roce40.params();
        let r100 = FabricKind::Roce100.params();
        let d40 = r40.one_way_ns(4096) - r40.base_one_way_ns;
        let d100 = r100.one_way_ns(4096) - r100.base_one_way_ns;
        assert!(d40 > 2 * d100);
    }
}
