//! Network fabric models.
//!
//! [`params`] holds wire-level constants for the paper's two fabrics
//! (InfiniBand EDR and RoCE) calibrated against Table 5's unloaded RTTs.
//! [`loopback`] is a *live* in-process fabric over shared memory and
//! threads used by the end-to-end examples — same dataplane code, real
//! wall-clock time, with ring-buffer RPC slots (zero-allocation framing,
//! windowed outstanding requests, lock-free per-shard receive lanes with
//! parking reactors), doorbell batched one-sided reads into caller-owned
//! scratch, and the PJRT batch engine on the hot path. [`affinity`]
//! pins shard reactor threads to cores (best-effort raw syscall, no-op
//! where unsupported).

pub mod affinity;
pub mod loopback;
pub mod params;

pub use params::{FabricKind, FabricParams};
