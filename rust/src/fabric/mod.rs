//! Network fabric models.
//!
//! [`params`] holds wire-level constants for the paper's two fabrics
//! (InfiniBand EDR and RoCE) calibrated against Table 5's unloaded RTTs.
//! [`loopback`] is a *live* in-process fabric over tokio channels used by
//! the end-to-end examples — same dataplane code, real wall-clock time,
//! with the PJRT batch engine on the hot path.

pub mod loopback;
pub mod params;

pub use params::{FabricKind, FabricParams};
