//! Network fabric models.
//!
//! [`params`] holds wire-level constants for the paper's two fabrics
//! (InfiniBand EDR and RoCE) calibrated against Table 5's unloaded RTTs.
//! [`loopback`] is a *live* in-process fabric over shared memory and
//! threads used by the end-to-end examples — same dataplane code, real
//! wall-clock time, with ring-buffer RPC slots (zero-allocation framing,
//! windowed outstanding requests, per-shard receive lanes), doorbell
//! batched one-sided reads, and the PJRT batch engine on the hot path.

pub mod loopback;
pub mod params;

pub use params::{FabricKind, FabricParams};
