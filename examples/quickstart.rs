//! Quickstart: the Storm API on an in-process reference cluster.
//!
//! Demonstrates the paper's two API surfaces (Tables 2 and 3):
//! * transactional: start_tx / add_to_read_set / add_to_write_set / commit
//! * data structure callbacks: lookup_start / lookup_end / rpc_handler
//!   (implemented by the MICA hash table)
//!
//! Run: `cargo run --example quickstart`

use storm::dataplane::local::LocalCluster;
use storm::dataplane::tx::{TxItem, TxOutcome};
use storm::ds::api::ObjectId;
use storm::ds::mica::MicaConfig;

const KV: ObjectId = ObjectId(0);

fn main() {
    // A 4-node cluster, each node holding a shard of one hash table.
    let cfg = MicaConfig { buckets: 1 << 14, width: 1, value_len: 112, store_values: false };
    let mut cluster = LocalCluster::new(4, vec![(KV, cfg)]);

    // Populate 10k items (round-robin to their hash owners).
    cluster.load(KV, 1..=10_000);
    println!("loaded 10k items across 4 shards");

    // --- One-two-sided lookups -----------------------------------------
    let mut client = cluster.client(false);
    let mut reads = 0;
    let mut rpcs = 0;
    for key in [1u64, 42, 999, 5_000, 9_999] {
        let res = cluster.run_lookup(&mut client, KV, key);
        assert!(res.found);
        reads += res.reads;
        rpcs += res.rpcs;
        println!(
            "lookup({key:>5}) -> version {} at node {} ({} read(s), {} rpc(s))",
            res.version, res.node, res.reads, res.rpcs
        );
    }
    println!("one-two-sided mix: {reads} one-sided reads, {rpcs} rpc fallbacks\n");

    // --- A read-write transaction ---------------------------------------
    // Read keys 1..3, update key 10, insert key 20_000, all atomically.
    let outcome = cluster.run_tx(
        &mut client,
        vec![TxItem::read(KV, 1), TxItem::read(KV, 2), TxItem::read(KV, 3)],
        vec![TxItem::update(KV, 10), TxItem::insert(KV, 20_000)],
    );
    match outcome {
        TxOutcome::Committed { write_results } => {
            println!("transaction committed: {write_results:?}");
        }
        TxOutcome::Aborted(reason) => println!("transaction aborted: {reason:?}"),
    }

    // The update bumped key 10's version; the insert is visible.
    let v10 = cluster.run_lookup(&mut client, KV, 10);
    let v20k = cluster.run_lookup(&mut client, KV, 20_000);
    println!("key 10 now at version {}; key 20000 found = {}", v10.version, v20k.found);
    assert_eq!(v10.version, 2);
    assert!(v20k.found);
    println!("\nquickstart OK");
}
