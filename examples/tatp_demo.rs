//! TATP on Storm (paper §6.2.3): correctness on the reference driver plus
//! throughput on the simulator.
//!
//! Part 1 runs real TATP transactions through the transactional protocol
//! on the in-process reference cluster and verifies database invariants
//! afterwards. Part 2 reproduces the Figure-6 comparison point.
//!
//! Run: `cargo run --release --example tatp_demo`

use storm::cluster::{SimConfig, StormMode, SystemKind, WorkloadKind, World};
use storm::dataplane::local::LocalCluster;
use storm::dataplane::tx::TxOutcome;
use storm::ds::mica::MicaConfig;
use storm::sim::{Pcg64, MICRO};
use storm::workload::tatp::{self, TatpPopulation, TatpWorkload};

fn main() {
    // --- Part 1: semantic check on the reference driver -----------------
    let subscribers = 2_000u64;
    let cfg = MicaConfig { buckets: 1 << 13, width: 2, value_len: 112, store_values: false };
    let objects = (0..4).map(|o| (storm::ds::api::ObjectId(o), cfg.clone())).collect();
    let mut cluster = LocalCluster::new(4, objects);
    for (obj, key) in TatpPopulation::new(subscribers).rows(7) {
        cluster.load(obj, std::iter::once(key));
    }
    let workload = TatpWorkload::new(subscribers);
    let mut rng = Pcg64::seeded(99);
    let mut client = cluster.client(false);
    let (mut commits, mut aborts) = (0u32, 0u32);
    let mut by_kind = std::collections::HashMap::new();
    for _ in 0..2_000 {
        let tx = workload.next_tx(&mut rng);
        let kind = tx.kind;
        match cluster.run_tx(&mut client, tx.read_set, tx.write_set) {
            TxOutcome::Committed { .. } => {
                commits += 1;
                *by_kind.entry(kind).or_insert(0u32) += 1;
            }
            TxOutcome::Aborted(_) => aborts += 1,
        }
    }
    println!("reference driver: {commits} commits, {aborts} aborts");
    for (kind, n) in &by_kind {
        println!("  {kind:?}: {n}");
    }
    assert_eq!(aborts, 0, "single-client run must not abort");
    // Every subscriber row must still resolve (updates never drop rows).
    for s in 1..=subscribers {
        assert!(cluster.run_lookup(&mut client, tatp::SUBSCRIBER, s).found);
    }
    println!("subscriber table intact after mixed workload\n");

    // --- Part 2: Figure-6 point on the simulator ------------------------
    println!("# TATP throughput, 16 nodes (Fig. 6 point)");
    for (label, mode, occ) in [
        ("Storm", StormMode::RpcOnly, 1.6),
        ("Storm(oversub)", StormMode::OneTwoSided, 0.45),
    ] {
        let mut cfg = SimConfig::new(SystemKind::Storm(mode), 16);
        cfg.workload = WorkloadKind::Tatp { subscribers_per_node: 2_000 };
        cfg.threads = 4;
        cfg.occupancy = occ;
        cfg.warmup = 150 * MICRO;
        cfg.measure = 800 * MICRO;
        let mut report = World::new(cfg).run();
        report.label = label.into();
        println!("{}", report.row());
    }
}
