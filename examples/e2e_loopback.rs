//! End-to-end driver: the full system composed, live.
//!
//! Starts a 4-node Storm cluster on the in-process loopback fabric (real
//! memory, real threads), loads 100k real key-value items, and drives a
//! mixed transactional workload from 3 client threads for several
//! seconds:
//!
//! * lookups go one-two-sided — one-sided byte reads of the owners'
//!   registered regions, RPC fallback on overflow chains;
//! * **address resolution runs through the AOT-compiled XLA artifacts via
//!   PJRT** (`artifacts/*.hlo.txt`, produced by `make artifacts`): each
//!   client thread loads the executables and batch-resolves its keys on
//!   the hot path — python never runs;
//! * 10% of operations are read-write Storm transactions (OCC with
//!   execution-phase locks, one-sided validation reads, RPC commits).
//!
//! Reports wall-clock throughput and latency percentiles; recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `make artifacts && cargo run --release --example e2e_loopback [seconds]`

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use storm::dataplane::live::LiveCluster;
use storm::dataplane::tx::{TxItem, TxOutcome};
use storm::ds::api::ObjectId;
use storm::ds::mica::MicaConfig;
use storm::runtime::Engine;
use storm::sim::{Histogram, Pcg64};

const NODES: u32 = 4;
const CLIENTS: u32 = 3;
const KEYS: u64 = 100_000;
const BATCH: usize = 64;

fn main() {
    let secs: u64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(5);
    let artifacts = std::path::Path::new("artifacts/lookup_batch.hlo.txt");
    if !artifacts.exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    // Oversubscribed width-1 table (Storm(oversub) geometry) with real
    // 112-byte values.
    let cfg = MicaConfig { buckets: 1 << 18, width: 1, value_len: 112, store_values: true };
    let cluster = LiveCluster::start(NODES, cfg);
    let t0 = Instant::now();
    cluster.load(1..=KEYS, |k| {
        let mut v = vec![0u8; 112];
        v[..8].copy_from_slice(&k.to_le_bytes());
        v
    });
    println!("loaded {KEYS} items into {NODES} shards in {:?}", t0.elapsed());

    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for id in 0..CLIENTS {
        let seed = cluster.client_seed(id % NODES);
        let stop = stop.clone();
        workers.push(std::thread::spawn(move || {
            // One PJRT client (compiled artifacts) per worker thread.
            let engine = Engine::load("artifacts").expect("load AOT artifacts");
            let mut client = seed.build(Some(engine));
            let mut rng = Pcg64::seeded(0xE2E + id as u64);
            let mut lat = Histogram::new();
            let mut lookups = 0u64;
            let mut found = 0u64;
            let mut commits = 0u64;
            let mut aborts = 0u64;
            let mut keybuf = Vec::with_capacity(BATCH);
            while !stop.load(Ordering::Relaxed) {
                // 90%: a batch of lookups resolved through the artifact.
                keybuf.clear();
                for _ in 0..BATCH {
                    keybuf.push(rng.gen_range(KEYS) + 1);
                }
                let start = Instant::now();
                let results = client.lookup_batch(&keybuf);
                let per_op = start.elapsed().as_nanos() as u64 / BATCH as u64;
                for r in &results {
                    lat.record(per_op);
                    lookups += 1;
                    found += r.found as u64;
                }
                // 10%: a read-write transaction.
                if rng.gen_bool(0.1 * BATCH as f64 / 64.0) {
                    let k1 = rng.gen_range(KEYS) + 1;
                    let k2 = rng.gen_range(KEYS) + 1;
                    let out = client.run_tx(
                        vec![TxItem::read(ObjectId(0), k1)],
                        vec![TxItem::update(ObjectId(0), k2).with_value(vec![id as u8; 112])],
                    );
                    match out {
                        TxOutcome::Committed { .. } => commits += 1,
                        TxOutcome::Aborted(_) => aborts += 1,
                    }
                }
            }
            (lookups, found, commits, aborts, lat)
        }));
    }

    std::thread::sleep(std::time::Duration::from_secs(secs));
    stop.store(true, Ordering::Relaxed);
    let mut lookups = 0u64;
    let mut found = 0u64;
    let mut commits = 0u64;
    let mut aborts = 0u64;
    let mut lat = Histogram::new();
    for w in workers {
        let (l, f, c, a, h) = w.join().unwrap();
        lookups += l;
        found += f;
        commits += c;
        aborts += a;
        lat.merge(&h);
    }
    let served = cluster.shutdown();

    let rate = lookups as f64 / secs as f64;
    println!("\n=== end-to-end results ({secs}s, {CLIENTS} client threads, {NODES} nodes) ===");
    println!(
        "lookups: {lookups} ({:.0} ops/s wall-clock), {:.2}% found",
        rate,
        100.0 * found as f64 / lookups.max(1) as f64
    );
    println!(
        "lookup latency: mean {:.1} us  p50 {:.1} us  p99 {:.1} us",
        lat.mean() / 1e3,
        lat.p50() as f64 / 1e3,
        lat.p99() as f64 / 1e3
    );
    println!("transactions: {commits} committed, {aborts} aborted");
    println!("rpc fallbacks served per node: {:?}", served.node_totals());
    println!("per-lane service counts (shard imbalance {:.2}):\n{served}", served.imbalance());
    assert!(found as f64 / lookups.max(1) as f64 > 0.99, "lookups must find loaded keys");
    assert!(commits > 0, "transactions must commit");
    println!("e2e_loopback OK");
}
