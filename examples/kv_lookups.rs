//! Key-value lookups at rack scale — the paper's core workload (§6.2.1),
//! on the calibrated cluster simulator.
//!
//! Sweeps the three Storm configurations over node counts and prints the
//! Figure-4-shaped series, plus a NIC-generation comparison showing how
//! the same dataplane behaves on CX3-class hardware (why the prior-work
//! designs made the choices they did).
//!
//! Run: `cargo run --release --example kv_lookups [nodes]`

use storm::cluster::{SimConfig, StormMode, SystemKind, World};
use storm::nic::NicGen;
use storm::sim::MICRO;

fn base(mode: StormMode, nodes: u32) -> SimConfig {
    let mut cfg = SimConfig::new(SystemKind::Storm(mode), nodes);
    cfg.threads = 4;
    cfg.keys_per_node = 10_000;
    cfg.warmup = 150 * MICRO;
    cfg.measure = 600 * MICRO;
    if mode == StormMode::RpcOnly {
        cfg.occupancy = 1.6;
    } else {
        cfg.occupancy = 0.45;
    }
    cfg
}

fn main() {
    let max_nodes: u32 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    println!("# KV lookups: Storm configurations vs cluster size (CX4, IB EDR)");
    for mode in [StormMode::RpcOnly, StormMode::OneTwoSided, StormMode::Perfect] {
        let mut n = 4;
        while n <= max_nodes {
            let report = World::new(base(mode, n)).run();
            println!("{}", report.row());
            n *= 2;
        }
    }

    println!("\n# Same dataplane, older NIC (CX3-class): the hardware the");
    println!("# prior systems were designed around");
    for gen in [NicGen::Cx3, NicGen::Cx4, NicGen::Cx5] {
        let mut cfg = base(StormMode::OneTwoSided, 8);
        cfg.nic = gen;
        let mut report = World::new(cfg).run();
        report.label = format!("Storm(oversub)/{}", gen.params().name);
        println!("{}", report.row());
    }
}
